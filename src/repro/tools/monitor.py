"""Cluster-wide monitoring plane (ORNL MELT + Arefin auditing papers).

Two consumers of the per-target instrumentation in ``core.metrics``:

* :class:`ClusterMonitor` — the MELT-style aggregation tree.  One
  collector client pulls a ``mon_collect`` leaf from EVERY MDS/OST over
  real, cost-bearing RPCs (single attempt, ``no_recover``) and merges
  them into one snapshot: per-target sections (NRS, DLM locks, grants,
  space, changelog, per-node counters, latency histograms) plus cluster
  roll-ups whose per-jobid quantiles come from *merging histogram
  buckets*, never from averaging per-target percentiles.  A crashed or
  partitioned target degrades the snapshot to ``partial`` with that
  target listed in ``stale`` — totals are computed over fresh leaves
  only, so they are never silently wrong, and the collector never hangs.
  The collector's own traffic is measured: every snapshot reports
  monitor RPCs as a fraction of workload RPCs (the ≤2% CI gate).

* :class:`ChangelogAnomalyDetector` — a changelog-stream consumer that
  tallies per-jobid operation rates per collection window and flags
  spikes against a rolling (EWMA) baseline: the auditing use-case that
  proves the plane sees real activity, tested with the noisy-neighbor
  personality of ``benchmarks/bench_scale.py``.
"""
from __future__ import annotations

from repro.core import metrics as metrics_mod
from repro.core import ptlrpc as R

MONITOR_JOBID = "monitor"


class ClusterMonitor:
    """Pull-based stats collector over ordinary ptlrpc imports.

    `max_exports` bounds the per-export section each target ships
    (busiest-N); `max_reconnects` bounds how long a dead target can
    stall collection (single-attempt requests + a short connect ring).
    """

    def __init__(self, cluster, node: R.Node | None = None,
                 max_exports: int = 32):
        self.cluster = cluster
        self.sim = cluster.sim
        self.max_exports = max_exports
        node = node or cluster.client_nodes[0]
        self.rpc = R.RpcClient(node)
        self.rpc.jobid = MONITOR_JOBID   # collector traffic is visible
        self.imports: dict[str, R.Import] = {}
        self.snapshots = 0
        for t in cluster.mds_targets:
            self._import(t.uuid, cluster.mds_nids[t.uuid], "mds")
        for t in cluster.ost_targets:
            self._import(t.uuid, cluster.ost_nids[t.uuid], "ost")

    def _import(self, uuid: str, nids, kind: str):
        imp = self.rpc.import_target(uuid, nids, kind)
        imp.max_reconnects = 2        # a dead target costs 2 timeouts, max
        self.imports[uuid] = imp

    # ------------------------------------------------------------ collect
    def _pull(self, uuid: str) -> dict:
        imp = self.imports[uuid]
        try:
            rep = imp.request("mon_collect",
                              {"max_exports": self.max_exports},
                              no_recover=True)
            return dict(rep.data, stale=False)
        except (R.TimeoutError_, R.RpcError):
            # crashed/partitioned target: this leaf is STALE — the
            # snapshot stays partial rather than hanging or guessing
            imp.state = "DISCONN"
            return {"uuid": uuid, "stale": True}

    def _monitor_rpcs(self) -> int:
        cnt = self.sim.stats.counters
        return (cnt.get("rpc.mds.mon_collect", 0)
                + cnt.get("rpc.ost.mon_collect", 0))

    def collect(self) -> dict:
        """One aggregation round: every target's leaf -> ONE tree."""
        t0 = self.sim.now
        mon0 = self._monitor_rpcs()
        leaves = {u: self._pull(u) for u in self.imports}
        fresh = [d for d in leaves.values() if not d["stale"]]
        stale = sorted(u for u, d in leaves.items() if d["stale"])

        def total(path, default=0):
            out = default
            for d in fresh:
                v = d
                for p in path:
                    v = v.get(p) if isinstance(v, dict) else None
                    if v is None:
                        break
                if v is not None:
                    out += v
            return out

        counters = {}
        for d in fresh:
            for k, v in (d.get("counters") or {}).items():
                counters[k] = counters.get(k, 0) + v
        cluster = {
            "counters": counters,
            "locks": {k: total(("locks", k)) for k in
                      ("resources", "granted", "waiting")},
            "grant": {"granted_total": total(("grant", "granted_total")),
                      "shrunk_bytes": total(("grant", "shrunk_bytes"))},
            "space": {"capacity": total(("space", "capacity")),
                      "free": total(("space", "free"))},
            "changelog": {
                "records": total(("changelog", "records")),
                "users": sum(len(d.get("changelog", {}).get("users", {}))
                             for d in fresh),
            },
            "spans": total(("latency", "spans")),
            "by_jobid": metrics_mod.merge_jobid_histograms(
                [d["latency"] for d in fresh if "latency" in d]),
        }
        self.snapshots += 1
        mon_rpcs = self._monitor_rpcs()
        all_rpcs = sum(n for k, n in self.sim.stats.counters.items()
                       if k.startswith("rpc.") and not
                       k.endswith(".mon_collect") and
                       k not in ("rpc.timeout", "rpc.replay",
                                 "rpc.reply_cache_hit"))
        snap = {
            "ts": round(self.sim.now, 6),
            "collect_vtime_s": round(self.sim.now - t0, 6),
            "partial": bool(stale),
            "stale": stale,
            "targets": {u: leaves[u] for u in sorted(leaves)},
            "cluster": cluster,
            "overhead": {
                "snapshot_rpcs": mon_rpcs - mon0,
                "monitor_rpcs_total": mon_rpcs,
                "workload_rpcs_total": all_rpcs,
                "ratio": round(mon_rpcs / all_rpcs, 6) if all_rpcs else 0.0,
            },
        }
        self.sim.stats.count("mon.snapshot")
        if stale:
            self.sim.stats.count("mon.snapshot_partial")
        self._last = snap
        return snap

    def info(self) -> dict:
        """procfs summary: last-snapshot shape without the whole tree."""
        last = getattr(self, "_last", None)
        out = {"snapshots": self.snapshots}
        if last is not None:
            out.update(ts=last["ts"], partial=last["partial"],
                       stale=last["stale"],
                       overhead_ratio=last["overhead"]["ratio"])
        return out


class ChangelogAnomalyDetector:
    """Per-jobid op-rate spike detection over the changelog streams.

    Registers a consumer on every MDT and, per :meth:`poll`, tallies the
    new records by jobid. A jobid is flagged when its window count
    exceeds ``spike_factor`` x its rolling EWMA baseline (and a noise
    floor ``min_ops``). The baseline only absorbs the window AFTER the
    comparison — a spike cannot vaccinate itself.
    """

    def __init__(self, cluster, monitor: ClusterMonitor | None = None,
                 spike_factor: float = 4.0, min_ops: int = 16,
                 alpha: float = 0.3):
        self.cluster = cluster
        self.spike_factor = spike_factor
        self.min_ops = min_ops
        self.alpha = alpha
        self.baseline: dict[str, float] = {}    # jobid -> EWMA ops/window
        self.windows = 0
        self.anomalies: list[dict] = []
        # consume over the monitor's rpc client (one observability plane)
        self.rpc = monitor.rpc if monitor else ClusterMonitor(cluster).rpc
        self.users: dict[str, str] = {}
        self.read_idx: dict[str, int] = {}
        for uuid in cluster.mds_nids:
            self.users[uuid] = cluster.lctl("changelog_register", uuid)
            self.read_idx[uuid] = 0

    def poll(self) -> list[dict]:
        """Consume new records, close one window, return new anomalies."""
        tally: dict[str, int] = {}
        for uuid in self.users:
            t = self.cluster.target(uuid)
            recs = t.changelog.read(since_idx=self.read_idx[uuid])
            for rec in recs:
                self.read_idx[uuid] = max(self.read_idx[uuid], rec.idx)
                jid = rec.jobid or "(none)"
                tally[jid] = tally.get(jid, 0) + 1
            if recs:
                t.changelog.clear(self.users[uuid], self.read_idx[uuid])
        self.windows += 1
        flagged = []
        for jid, n in sorted(tally.items()):
            base = self.baseline.get(jid)
            if base is not None and n >= self.min_ops \
                    and n > self.spike_factor * base:
                flagged.append({"jobid": jid, "ops": n,
                                "baseline": round(base, 3),
                                "window": self.windows})
                self.cluster.stats.count("mon.anomaly")
            # EWMA update AFTER the spike test
            self.baseline[jid] = (n if base is None
                                  else (1 - self.alpha) * base
                                  + self.alpha * n)
        self.anomalies.extend(flagged)
        return flagged

    def close(self):
        for uuid, user in self.users.items():
            self.cluster.lctl("changelog_deregister", uuid, user)
        self.users.clear()
