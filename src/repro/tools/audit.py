"""Robinhood-style changelog auditor (arXiv:1505.02656, arXiv:2302.14824).

Consumes the per-MDT changelog streams of a (possibly striped-namespace)
cluster and maintains an out-of-band **namespace mirror** — the core trick
of Lustre activity-tracking tools: after an initial scan (here: starting
from an empty filesystem), the mirror stays in sync by applying changelog
records only, never re-walking the namespace. `verify()` then proves the
mirror equals the client-visible `readdir`/`stat` ground truth.

Stream merge across MDTs: each MDT's changelog is totally ordered by its
record index; across MDTs the virtual timestamp orders records (ties
broken by (mdt, idx)). Cross-MDT operations appear twice — a nameless
inode-half record on the remote MDT (``extra.remote``) and the
name-bearing record on the coordinator — so the mirror applies namespace
structure from coordinator records only and skips remote halves.

Usage:
    aud = ChangelogAuditor(client)      # registers on ALL MDTs
    ... workload ...
    aud.tail()                          # pull + merge + apply + clear
    report = aud.verify()               # mirror vs readdir/stat truth
    assert report["ok"]
"""
from __future__ import annotations

from repro.core import changelog as cl_mod
from repro.core.mds import ROOT_FID


class NamespaceMirror:
    """A shadow namespace rebuilt purely from changelog records.

    Tracks, per inode fid: type, the set of (parent fid, name) links, and
    size/mode when SETATTR/CLOSE records supplied them. The mirror does
    its own link accounting — a file node dies when its last link is
    removed — so UNLINK records need no "last link" hint (though the MDS
    provides one when it knows)."""

    def __init__(self):
        self.nodes: dict[tuple, dict] = {
            ROOT_FID: {"type": "dir", "links": set()}}
        self.children: dict[tuple, dict] = {ROOT_FID: {}}
        self.applied = 0
        self.skipped_remote = 0

    # ------------------------------------------------------------ helpers
    def _add_node(self, fid: tuple, ftype: str):
        node = self.nodes.setdefault(fid, {"type": ftype, "links": set()})
        if ftype == "dir":
            self.children.setdefault(fid, {})
        return node

    def _add_link(self, fid: tuple, pfid: tuple, name: str):
        old = self.children.get(pfid, {}).get(name)
        if old is not None and old != fid:
            self._unlink_name(pfid, name)      # displace the old entry
        self.nodes[fid]["links"].add((pfid, name))
        self.children.setdefault(pfid, {})[name] = fid

    def _unlink_name(self, pfid: tuple, name: str):
        old = self.children.get(pfid, {}).pop(name, None)
        if old is None:
            return
        node = self.nodes.get(old)
        if node is None:
            return
        node["links"].discard((pfid, name))
        if not node["links"]:
            self.nodes.pop(old, None)
            self.children.pop(old, None)

    # -------------------------------------------------------------- apply
    def apply(self, rec: dict):
        """Apply one wire-format record (`ChangelogRecord.to_wire`)."""
        extra = rec.get("extra") or {}
        if extra.get("remote"):
            # inode half of a cross-MDT op; the coordinator's name-bearing
            # record carries the namespace change
            self.skipped_remote += 1
            return
        t = rec["type"]
        fid = tuple(rec["fid"]) if rec.get("fid") else None
        pfid = tuple(rec["pfid"]) if rec.get("pfid") else None
        name = rec.get("name", "")
        if t in (cl_mod.CL_CREAT, cl_mod.CL_MKDIR, cl_mod.CL_SYMLINK):
            ftype = {cl_mod.CL_CREAT: "file", cl_mod.CL_MKDIR: "dir",
                     cl_mod.CL_SYMLINK: "symlink"}[t]
            node = self._add_node(fid, ftype)
            if "mode" in extra:
                node["mode"] = extra["mode"]
            self._add_link(fid, pfid, name)
        elif t == cl_mod.CL_LINK:
            self._add_node(fid, self.nodes.get(fid, {}).get("type", "file"))
            self._add_link(fid, pfid, name)
        elif t in (cl_mod.CL_UNLINK, cl_mod.CL_RMDIR):
            self._unlink_name(pfid, name)
        elif t == cl_mod.CL_RENAME:
            spfid = tuple(extra["spfid"])
            self._unlink_name_keep(spfid, extra["sname"])
            self._add_node(fid, self.nodes.get(fid, {}).get("type", "file"))
            self._add_link(fid, pfid, name)
        elif t == cl_mod.CL_SETATTR:
            node = self.nodes.get(fid)
            if node is not None:
                attrs = extra.get("attrs", {})
                for k in ("mode", "uid", "gid", "size"):
                    if k in attrs:
                        node[k] = attrs[k]
        elif t == cl_mod.CL_CLOSE:
            node = self.nodes.get(fid)
            if node is not None:
                node["size"] = extra.get("size", node.get("size"))
        self.applied += 1

    def _unlink_name_keep(self, pfid: tuple, name: str):
        """Remove a directory entry WITHOUT killing the node (rename
        source side: the inode moves, it does not die)."""
        old = self.children.get(pfid, {}).pop(name, None)
        if old is not None and old in self.nodes:
            self.nodes[old]["links"].discard((pfid, name))


class ChangelogAuditor:
    """Tails the changelogs of ALL MDTs behind one client mount, merging
    the per-MDT streams by timestamp into a single ordered activity feed
    that drives a NamespaceMirror."""

    def __init__(self, client, bootstrap: bool = False):
        self.client = client
        self.lmv = client.lmv
        self.mirror = NamespaceMirror()
        self.feed: list[dict] = []          # merged, ordered activity
        self.users: dict[int, str] = {}     # mdt idx -> consumer id
        self.applied_idx: dict[int, int] = {}
        for i, mdc in enumerate(self.lmv.mdcs):
            self.users[i] = mdc.changelog_register()
            self.applied_idx[i] = 0
        if bootstrap:
            self.bootstrap_scan()

    # ---------------------------------------------------------- bootstrap
    def bootstrap_scan(self):
        """Initial scan of an already-populated namespace (the Robinhood
        bootstrap): consumers are registered FIRST (above), so everything
        that changes during the walk is recorded; the walk then loads the
        readdir/getattr ground truth into the mirror; the closing tail()
        replays whatever raced the scan — record application is
        idempotent against already-scanned state (links are sets, entry
        inserts displace)."""
        for pfid, name, fid, attrs in self.client.walk():
            node = self.mirror._add_node(fid, attrs["type"])
            if attrs.get("mode") is not None:
                node["mode"] = attrs["mode"]
            if attrs["type"] == "file" and not attrs.get("mtime_on_ost"):
                node["size"] = attrs["size"]
            self.mirror._add_link(fid, pfid, name)
        self.tail()

    # --------------------------------------------------------------- tail
    def tail(self, clear: bool = True) -> int:
        """Pull new records from every MDT, merge by (time, mdt, idx),
        apply to the mirror, and (by default) acknowledge them. Returns
        the number of records applied."""
        batch = []
        for i, mdc in enumerate(self.lmv.mdcs):
            for rec in mdc.changelog_read(self.users[i],
                                          since_idx=self.applied_idx[i]):
                batch.append((rec.get("time", 0.0), i, rec["idx"], rec))
        batch.sort(key=lambda t: t[:3])
        for time_, mdt, idx, rec in batch:
            self.mirror.apply(rec)
            self.feed.append(dict(rec, mdt=mdt))
            self.applied_idx[mdt] = max(self.applied_idx[mdt], idx)
        if clear:
            # only ack MDTs that contributed to THIS batch — an idle MDT
            # gets no redundant clear RPC (and no server-side purge scan)
            for mdt in sorted({m for _, m, _, _ in batch}):
                self.lmv.mdcs[mdt].changelog_clear(
                    self.users[mdt], self.applied_idx[mdt])
        return len(batch)

    def close(self):
        for i, mdc in enumerate(self.lmv.mdcs):
            mdc.changelog_deregister(self.users[i])
        self.users.clear()

    # ------------------------------------------------------------- verify
    def verify(self) -> dict:
        """Walk the real namespace (client-visible readdir/stat ground
        truth, split-directory buckets included) and diff it against the
        mirror. Returns {"ok", "mismatches", "dirs", "entries"}."""
        mism = []
        reachable = {ROOT_FID}
        stack = [ROOT_FID]
        seen = {ROOT_FID}
        n_dirs = n_entries = 0
        while stack:
            dfid = stack.pop()
            n_dirs += 1
            out = self.lmv.readdir(dfid)
            truth = {k: tuple(v) for k, v in out["entries"].items()}
            mine = dict(self.mirror.children.get(dfid, {}))
            if truth != mine:
                mism.append({"kind": "entries", "dir": dfid,
                             "truth": truth, "mirror": mine})
            for name, fid in truth.items():
                n_entries += 1
                reachable.add(fid)
                attrs = self.lmv.getattr(fid)["attrs"]
                node = self.mirror.nodes.get(fid)
                if node is None:
                    mism.append({"kind": "missing", "fid": fid,
                                 "name": name})
                    continue
                if node["type"] != attrs["type"]:
                    mism.append({"kind": "type", "fid": fid, "name": name,
                                 "truth": attrs["type"],
                                 "mirror": node["type"]})
                if (attrs["type"] == "file" and "size" in node
                        and not attrs.get("mtime_on_ost")
                        and node["size"] != attrs["size"]):
                    mism.append({"kind": "size", "fid": fid, "name": name,
                                 "truth": attrs["size"],
                                 "mirror": node["size"]})
                if attrs["type"] == "dir" and fid not in seen:
                    seen.add(fid)
                    stack.append(fid)
        for fid in set(self.mirror.nodes) - reachable:
            mism.append({"kind": "extra", "fid": fid,
                         "mirror": self.mirror.nodes[fid]})
        return {"ok": not mism, "mismatches": mism,
                "dirs": n_dirs, "entries": n_entries}
