"""HLO-text cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
program that scans over layers (all of ours) is undercounted by ~n_layers x.
This analyzer parses the post-optimization HLO text, extracts while-loop trip
counts, propagates multipliers through the call graph (while bodies, fusions,
calls), and sums:

  * dot/convolution FLOPs            (per-device, SPMD-partitioned shapes)
  * HBM traffic model: operand+result bytes of top-level compute ops
  * collective bytes by kind (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute), operand sizes

All numbers are PER DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count=\{"?n"?[:=]"?(\d+)"?\}')
_TRIP_RE2 = re.compile(r'"known_trip_count":\s*\{\s*"n"\s*:\s*"?(\d+)"?')

BOOKKEEPING = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "get-dimension-size",
    "opt-barrier", "bitcast-convert",
    # control flow: bodies are accounted through the call graph; counting
    # the op itself would charge the whole carried tuple per call site
    "while", "conditional", "call",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "collective-broadcast", "ragged-all-to-all",
}


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dt, dims = m.groups()
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dt, shape


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    rest: str            # full remainder of line after opcode(
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: list


def parse_hlo(text: str):
    """Parse computations from HLO text. Returns (comps, entry_name)."""
    comps = {}
    entry = None
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        # Computation headers end with "{", contain "->", and are not
        # assignments (op lines start "%name = ..."), e.g.:
        #   %region_1.1_spmd.clone (param: (s32[], ...)) -> (...) {
        is_header = (stripped.endswith("{") and " -> " in stripped
                     and not re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s+=", stripped))
        if is_header:
            mc = _COMP_RE.match(stripped)
            if mc:
                cur = Computation(mc.group(1), [])
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        mo = _OP_RE.match(line)
        if mo:
            name, rtype, kind, rest = mo.groups()
            cur.ops.append(Op(name, kind, rtype, rest,
                              stripped.startswith("ROOT")))
    return comps, entry


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _operand_names(op: Op):
    return _OPERAND_RE.findall(op.rest.split(")")[0])


def _dot_flops(op: Op, symtab) -> int:
    """2 * prod(result) * prod(lhs contracting dims)."""
    _, rshape = shape_elems(op.result_type)
    names = _operand_names(op)
    if not names or names[0] not in symtab:
        return 0
    _, lhs_shape = shape_elems(symtab[names[0]])
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contract = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            contract *= lhs_shape[int(d)] if int(d) < len(lhs_shape) else 1
    return 2 * math.prod(rshape) * contract


def _conv_flops(op: Op, symtab) -> int:
    # 2 * prod(result) * (kernel elements / out_channels)
    _, rshape = shape_elems(op.result_type)
    names = _operand_names(op)
    if len(names) < 2 or names[1] not in symtab:
        return 0
    _, kshape = shape_elems(symtab[names[1]])
    kelems = math.prod(kshape) if kshape else 1
    out_c = rshape[-1] if rshape else 1
    return 2 * math.prod(rshape) * max(1, kelems // max(1, out_c))


def _while_trip_count(op: Op, comps, const_cache) -> int:
    m = _TRIP_RE.search(op.rest) or _TRIP_RE2.search(op.rest)
    if m:
        return int(m.group(1))
    # fall back: max s32 constant in the condition computation
    cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
    if cm and cm.group(1) in comps:
        cond = comps[cm.group(1)]
        consts = []
        for o in cond.ops:
            if o.kind == "constant" and "s32[]" in o.result_type:
                c = re.search(r"constant\((\d+)\)", "constant(" + o.rest)
                if c:
                    consts.append(int(c.group(1)))
        if consts:
            return max(consts)
    return 1


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_counts: dict = dataclasses.field(default_factory=dict)
    flops_by_kind: dict = dataclasses.field(default_factory=dict)

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(text: str) -> CostReport:
    comps, entry = parse_hlo(text)
    if entry is None:
        # fall back: computation never referenced as callee
        callees = set()
        for c in comps.values():
            for op in c.ops:
                callees.update(_CALLS_RE.findall(op.rest))
        entry = next((n for n in comps if n not in callees),
                     next(iter(comps)))

    mult = defaultdict(float)        # all computations (flops/collectives)
    top_mult = defaultdict(float)    # non-fused computations (HBM traffic)
    report = CostReport()

    def visit(comp_name: str, m: float, seen, fused: bool):
        if comp_name not in comps or comp_name in seen:
            return
        comp = comps[comp_name]
        mult[comp_name] += m
        if not fused:
            top_mult[comp_name] += m
        for op in comp.ops:
            if op.kind == "while":
                trips = _while_trip_count(op, comps, None)
                report.n_while += 1
                report.trip_counts[op.name] = trips
                for cal in _CALLS_RE.findall(op.rest):
                    visit(cal, m * trips, seen | {comp_name}, fused)
            elif op.kind in ("call", "conditional"):
                for cal in _CALLS_RE.findall(op.rest):
                    visit(cal, m, seen | {comp_name}, fused)
            elif op.kind in ("fusion", "custom-call", "reduce", "map",
                             "scatter", "sort", "select-and-scatter",
                             "reduce-window"):
                for cal in _CALLS_RE.findall(op.rest):
                    visit(cal, m, seen | {comp_name}, True)

    visit(entry, 1.0, frozenset(), False)

    symtab = {}
    for comp in comps.values():
        for op in comp.ops:
            symtab[op.name] = op.result_type

    for cname, m_all in mult.items():
        comp = comps[cname]
        m_top = top_mult.get(cname, 0.0)
        for op in comp.ops:
            m = m_all
            if op.kind == "dot":
                f = _dot_flops(op, symtab) * m
                report.flops += f
                report.flops_by_kind["dot"] = (
                    report.flops_by_kind.get("dot", 0.0) + f)
            elif op.kind == "convolution":
                f = _conv_flops(op, symtab) * m
                report.flops += f
                report.flops_by_kind["convolution"] = (
                    report.flops_by_kind.get("convolution", 0.0) + f)
            if op.kind in COLLECTIVES:
                kind = op.kind.replace("-start", "")
                b = _operand_bytes(op, symtab) * m
                report.collective_bytes += b
                report.collectives[kind] = report.collectives.get(kind, 0) + b
            # HBM traffic model: top-level non-bookkeeping ops move their
            # operands + result through HBM once per execution. In-place
            # slice updates only move the slice, not the aliased buffer.
            # Ops inside fused computations don't touch HBM.
            m = m_top
            if m == 0.0:
                continue
            if op.kind == "dynamic-update-slice":
                names = _operand_names(op)
                upd = (shape_bytes(symtab.get(names[1], ""))
                       if len(names) > 1 else 0)
                report.traffic_bytes += 2 * upd * m
            elif op.kind == "dynamic-slice" or op.kind == "slice":
                report.traffic_bytes += 2 * shape_bytes(op.result_type) * m
            elif op.kind == "fusion":
                report.traffic_bytes += _fusion_traffic(
                    op, comps, symtab) * m
            elif op.kind not in BOOKKEEPING and not op.kind.endswith("-done"):
                report.traffic_bytes += (
                    shape_bytes(op.result_type)
                    + _operand_bytes(op, symtab)) * m
    return report


def _fusion_traffic(op: Op, comps, symtab) -> int:
    """HBM bytes moved by one fusion execution.

    A fused computation only reads the elements it actually consumes: an
    operand whose every use inside the fusion is a (dynamic-)slice is
    charged at slice size (this is how scan-over-stacked-params reads one
    layer per iteration), and a root dynamic-update-slice writes (and
    aliases) only the updated slice."""
    mm = _CALLS_RE.search(op.rest)
    comp = comps.get(mm.group(1)) if mm else None
    if comp is None:
        return shape_bytes(op.result_type) + _operand_bytes(op, symtab)
    names = _operand_names(op)
    param_idx = {}
    for o in comp.ops:
        if o.kind == "parameter":
            mi = re.match(r"(\d+)", o.rest)
            if mi:
                param_idx[o.name] = int(mi.group(1))
    read_bytes = {i: shape_bytes(symtab.get(n, ""))
                  for i, n in enumerate(names)}
    uses = defaultdict(list)
    for o in comp.ops:
        for n in _operand_names(o):
            if n in param_idx:
                uses[param_idx[n]].append(o)
    local_ty = {o.name: o.result_type for o in comp.ops}
    for idx, ops_u in uses.items():
        if ops_u and all(u.kind in ("dynamic-slice", "slice")
                         for u in ops_u):
            read_bytes[idx] = sum(shape_bytes(u.result_type) for u in ops_u)
    # Pass-through scan buffers: a dynamic-update-slice inside the fusion
    # whose buffer dims equal the fusion result dims means the big buffer is
    # aliased in place (XLA-CPU sometimes wraps it in dtype-roundtrip
    # converts; on TPU it is a true in-place update). Charge the update
    # slice, not the buffer.
    dus_update = {}
    for o in comp.ops:
        if o.kind == "dynamic-update-slice":
            dn = _operand_names(o)
            if len(dn) > 1:
                dus_update[shape_elems(o.result_type)[1]] = shape_bytes(
                    local_ty.get(dn[1], symtab.get(dn[1], "")))
    out_dims = shape_elems(op.result_type)[1]
    out_b = shape_bytes(op.result_type)
    if out_dims in dus_update:
        out_b = dus_update[out_dims]
        for pname, idx in param_idx.items():
            if shape_elems(local_ty.get(pname, ""))[1] == out_dims:
                read_bytes[idx] = min(read_bytes.get(idx, 0),
                                      dus_update[out_dims])
    total_in = sum(read_bytes.values())
    return total_in + out_b


def _operand_bytes(op: Op, symtab) -> int:
    return sum(shape_bytes(symtab.get(n, "")) for n in _operand_names(op))


def _fusion_root(op: Op, comps):
    m = _CALLS_RE.search(op.rest)
    if not m or m.group(1) not in comps:
        return None
    comp = comps[m.group(1)]
    for o in comp.ops:
        if o.is_root:
            return o
    return comp.ops[-1] if comp.ops else None


def _fusion_root_kind(op: Op, comps):
    r = _fusion_root(op, comps)
    return r.kind if r else None


def analyze_compiled(compiled) -> CostReport:
    return analyze(compiled.as_text())
