"""Top-K cost breakdown from compiled HLO text — the dry-run "profiler".

Reports the heaviest individual ops (collectives by wire bytes, dots by
FLOPs, top-level fusions by HBM traffic), each multiplied by its loop trip
count, with the while-loop context — this is what the §Perf hypothesis
loop reads instead of a wall-clock trace.
"""
from __future__ import annotations

from collections import defaultdict

from repro.tools import hlo_cost as H


def top_costs(text: str, k: int = 12) -> dict:
    comps, entry = H.parse_hlo(text)
    if entry is None:
        callees = set()
        for c in comps.values():
            for op in c.ops:
                callees.update(H._CALLS_RE.findall(op.rest))
        entry = next((n for n in comps if n not in callees),
                     next(iter(comps)))

    mult = defaultdict(float)
    top_mult = defaultdict(float)

    def visit(name, m, seen, fused):
        if name not in comps or name in seen:
            return
        mult[name] += m
        if not fused:
            top_mult[name] += m
        for op in comps[name].ops:
            if op.kind == "while":
                trips = H._while_trip_count(op, comps, None)
                for cal in H._CALLS_RE.findall(op.rest):
                    visit(cal, m * trips, seen | {name}, fused)
            elif op.kind in ("call", "conditional"):
                for cal in H._CALLS_RE.findall(op.rest):
                    visit(cal, m, seen | {name}, fused)
            elif op.kind in ("fusion", "custom-call", "reduce", "map",
                             "scatter", "sort", "select-and-scatter",
                             "reduce-window"):
                for cal in H._CALLS_RE.findall(op.rest):
                    visit(cal, m, seen | {name}, True)

    visit(entry, 1.0, frozenset(), False)

    symtab = {}
    for comp in comps.values():
        for op in comp.ops:
            symtab[op.name] = op.result_type

    colls, dots, fusions = [], [], []
    for cname, m in mult.items():
        for op in comps[cname].ops:
            if op.kind in H.COLLECTIVES:
                b = H._operand_bytes(op, symtab) * m
                colls.append((b, op.kind, op.name, cname, m,
                              op.result_type[:60]))
            elif op.kind == "dot":
                f = H._dot_flops(op, symtab) * m
                dots.append((f, op.kind, op.name, cname, m,
                             op.result_type[:60]))
        mt = top_mult.get(cname, 0.0)
        if mt:
            for op in comps[cname].ops:
                if op.kind == "fusion":
                    t = H._fusion_traffic(op, comps, symtab) * mt
                    fusions.append((t, op.kind, op.name, cname, mt,
                                    op.result_type[:60]))
    colls.sort(reverse=True)
    dots.sort(reverse=True)
    fusions.sort(reverse=True)
    return {"collectives": colls[:k], "dots": dots[:k],
            "fusions": fusions[:k]}


def print_top(text: str, k: int = 10):
    out = top_costs(text, k)
    for section, unit in (("collectives", "B"), ("dots", "F"),
                          ("fusions", "B")):
        print(f"--- top {section} ---")
        for v, kind, name, cname, m, ty in out[section]:
            print(f"  {v:.3e}{unit}  x{m:<6.0f} {kind:<18} {name:<28} "
                  f"in {cname[:40]:<40} {ty}")
    return out
