"""Three-term roofline from the dry-run's compiled artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(The SPMD module is the per-device program, so per-device / per-chip-rate is
identical to the spec's global / (chips x rate).)
"""
from __future__ import annotations

import dataclasses

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.tools.hlo_cost import CostReport


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # 6*N*D (global, per step)
    hlo_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs_global
    bound_s: float               # max of the three terms
    mfu_bound: float             # model_flops / (chips*peak) / bound_s

    def as_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg, rc) -> float:
    """6*N*D for train, 2*N*D for prefill, 2*N_active*B per decoded token.

    N excludes embedding gathers (standard convention); MoE uses active
    params. Attention flops excluded (convention), reported separately by
    the HLO analyzer."""
    n_act = cfg.n_active_params
    if rc.kind == "train":
        return 6.0 * n_act * rc.global_batch * rc.seq_len
    if rc.kind == "prefill":
        return 2.0 * n_act * rc.global_batch * rc.seq_len
    return 2.0 * n_act * rc.global_batch  # decode: one token per sequence


def compute(report: CostReport, cfg, rc, n_chips: int) -> Roofline:
    c = report.flops / PEAK_FLOPS_BF16
    m = report.traffic_bytes / HBM_BW
    x = report.collective_bytes / ICI_BW
    terms = {"compute": c, "memory": m, "collective": x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, rc)
    hlo_global = report.flops * n_chips
    bound = max(c, m, x)
    mfu = (mf / (n_chips * PEAK_FLOPS_BF16)) / bound if bound > 0 else 0.0
    return Roofline(c, m, x, dominant, mf, hlo_global,
                    mf / hlo_global if hlo_global else 0.0, bound, mfu)
