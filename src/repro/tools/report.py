"""Render the §Dry-run / §Roofline markdown tables from results/dryrun."""
from __future__ import annotations

import glob
import json
import os


def load(results_dir="results/dryrun"):
    out = {}
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        base = os.path.basename(f)
        # skip hillclimb runs with override suffixes (arch_shape_Npod.json
        # is the canonical record)
        if not (base.endswith("_1pod.json") or base.endswith("_2pod.json")):
            continue
        d = json.load(open(f))
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def roofline_table(cells: dict, mesh: str = "16x16") -> str:
    rows = []
    for (arch, shape, m), d in sorted(cells.items(),
                                      key=lambda kv: (kv[0][1], kv[0][0])):
        if m != mesh:
            continue
        r, mem = d["roofline"], d["memory"]
        peak = (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9
        dom = {"compute": "comp", "memory": "mem", "collective": "coll"}[
            r["dominant"]]
        rows.append(
            f"| {arch} | {shape} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {dom} | "
            f"{r['useful_ratio']:.3f} | {r['mfu_bound']:.3f} | "
            f"{peak:.1f} | {'yes' if peak <= 16.0 else 'NO'} |")
    head = ("| arch | shape | compute s | memory s | collective s | bound "
            "| useful | MFU<= | GB/dev | fits |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def dryrun_summary(cells: dict) -> str:
    lines = []
    for mesh in ("16x16", "2x16x16"):
        sub = [d for (a, s, m), d in cells.items() if m == mesh]
        if not sub:
            continue
        n_fit = sum(1 for d in sub
                    if (d["memory"]["argument_bytes"]
                        + d["memory"]["temp_bytes"]) <= 16e9)
        t = sum(d["compile_s"] for d in sub)
        lines.append(f"* **{mesh}** ({sub[0]['n_chips']} chips): "
                     f"{len(sub)}/{len(sub)} cells lower+compile OK, "
                     f"{n_fit}/{len(sub)} fit 16 GB/chip, "
                     f"total compile {t:.0f}s")
    return "\n".join(lines)


def collective_mix(cells: dict, arch: str, shape: str,
                   mesh: str = "2x16x16") -> str:
    d = cells.get((arch, shape, mesh))
    if not d:
        return ""
    colls = d["hlo_cost"]["collectives"]
    return ", ".join(f"{k}={v:.2e}B" for k, v in sorted(
        colls.items(), key=lambda kv: -kv[1]))


if __name__ == "__main__":
    cells = load()
    print(dryrun_summary(cells))
    print()
    print(roofline_table(cells))
