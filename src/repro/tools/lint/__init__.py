"""lustre-lint: protocol-discipline static analyzer.

Eight PRs of this repo accumulated unwritten protocol disciplines; this
package checks them mechanically on every CI run (`python -m
repro.tools.lint src/`).  The rules (ids in parentheses):

  * ``txn-scope``      — mutating (transno-bearing) ``op_*``/``_reint_*``
    handlers must open an undo-scoped transaction (``self.txn`` /
    ``self.txn_meta`` / a FilterDevice mutator wired to ``txn_hook``).
  * ``emit-in-txn``    — every ``changelog.emit`` (or a forwarding
    wrapper like ``MdsTarget._cl``) must assign its record and retract
    it inside a registered transaction undo; llog catalog writes outside
    the llog/changelog implementation layer need the same scope.
  * ``fail-site``      — every ``OBD_FAIL`` checkpoint callsite
    (``maybe_fail``/``note``/``state.check``/``state.defer``) names a
    site registered in ``core/fail.py`` and every registered site has at
    least one callsite (no dead sites).
  * ``fail-sweep``     — the machine-readable site inventory
    (``fail_sites.json``) the crash sweep parametrizes over matches the
    registry + callsites exactly, so sweep coverage can never silently
    drift (regenerate with ``--write-inventory``).
  * ``replay-coverage``— every op name registered in a handler table is
    either reply-cache-covered (its handler returns a transno, so the
    reply-cache/replay protocol gives exactly-once) or appears in the
    replay-idempotence test matrix (``tests/replay_matrix.py``) with a
    stated mechanism.
  * ``rpc-under-lock`` — no RPC issued while a function holds a local
    DLM resource mid-transition (mutated ``res.granted``/``res.waiting``)
    unless the callsite carries a ``# lint: rpc-under-lock(reason)``
    annotation.

Suppression syntax (reviewed exceptions): ``# lint: ok(rule[,rule]: why)``
on the offending line, or on a ``def`` line to cover the whole function.
Known-issue deferrals live in ``baseline.json`` next to this package.
See ``src/repro/core/README.md`` for the full discipline documentation.
"""
from repro.tools.lint.analyzer import (  # noqa: F401
    Finding, LintResult, run_lint, load_inventory, write_inventory,
    INVENTORY_PATH, BASELINE_PATH, RULES,
)
