"""CLI: ``python -m repro.tools.lint src/ [options]``.

Exit status: 0 = clean (suppressed/baselined findings allowed),
1 = unsuppressed findings, 2 = usage error.
"""
from __future__ import annotations

import argparse
import sys

from repro.tools.lint.analyzer import (
    BASELINE_PATH, INVENTORY_PATH, run_lint, write_inventory)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.lint",
        description="lustre-lint: protocol-discipline static analyzer")
    ap.add_argument("paths", nargs="+",
                    help="files/trees to scan (repro/core + repro/fsio)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--write-inventory", action="store_true",
                    help="regenerate the OBD_FAIL site inventory the "
                         "crash sweep parametrizes over, then re-check")
    ap.add_argument("--inventory", default=str(INVENTORY_PATH),
                    help="site inventory path (default: packaged)")
    ap.add_argument("--matrix", default=None,
                    help="replay-idempotence matrix "
                         "(default: <tree>/tests/replay_matrix.py)")
    ap.add_argument("--baseline", default=str(BASELINE_PATH),
                    help="known-issue baseline file")
    args = ap.parse_args(argv)

    res = run_lint(args.paths, inventory_path=args.inventory,
                   matrix_path=args.matrix, baseline_path=args.baseline)
    if args.write_inventory and res.inventory is not None:
        write_inventory(res.inventory, args.inventory)
        # re-run so fail-sweep findings reflect the fresh inventory
        res = run_lint(args.paths, inventory_path=args.inventory,
                       matrix_path=args.matrix, baseline_path=args.baseline)

    if args.json:
        import json
        print(json.dumps({
            "files_scanned": res.files_scanned,
            "failures": len(res.failures),
            "suppressed": res.suppressed,
            "baselined": res.baselined,
            "findings": [vars(f) for f in res.findings],
        }, indent=1))
    else:
        for f in res.findings:
            print(f.render())
        print(f"lustre-lint: {res.files_scanned} files, "
              f"{len(res.failures)} finding(s), "
              f"{res.suppressed} suppressed, {res.baselined} baselined")
    return 1 if res.failures else 0


if __name__ == "__main__":
    sys.exit(main())
