"""AST implementation of the lustre-lint protocol-discipline rules.

The analyzer is a plain two-phase pass: phase one walks every module
under ``repro/core`` + ``repro/fsio`` collecting facts (handler tables,
transno-bearing replies, fail-site callsites, emit sites, DLM state
mutations, RPC calls); phase two evaluates the rules over the collected
facts.  Everything is derived from the source — no imports of the
checked code — so the tool runs on a seeded/broken tree without
executing it.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path

RULES = ("txn-scope", "emit-in-txn", "fail-site", "fail-sweep",
         "replay-coverage", "rpc-under-lock")

_PKG_DIR = Path(__file__).resolve().parent
INVENTORY_PATH = _PKG_DIR / "fail_sites.json"
BASELINE_PATH = _PKG_DIR / "baseline.json"

# FilterDevice methods wired to txn_hook (ost.py: obd.txn_hook = self.txn):
# calling one of these from a handler opens the backend transaction.
OBD_MUTATORS = {"create", "destroy", "setattr", "write", "writev", "punch"}
# Changelog methods that open their own header transaction internally
# (Changelog is constructed with txn=self.txn).
CHANGELOG_TXN_METHODS = {"register", "deregister", "clear"}
# Modules that ARE the emit/llog implementation layer: the write
# primitives live here, txn scoping is their constructor contract
# (txn= hook), so the caller-side emit rule does not apply inside them.
EMIT_IMPL_MODULES = {"changelog.py", "llog.py", "fail.py"}
# svc_kind values an f-string fail site may expand over.
SVC_KINDS = ("mds", "ost")

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*ok\(([^):]*)")
_ANNOT_RE = re.compile(r"#\s*lint:\s*rpc-under-lock\(")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str          # repo-relative-ish display path
    line: int
    message: str
    symbol: str = ""   # enclosing Class.method, for baseline matching
    suppressed: bool = False
    baselined: bool = False

    def render(self) -> str:
        tag = " [suppressed]" if self.suppressed else (
            " [baselined]" if self.baselined else "")
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


@dataclasses.dataclass
class LintResult:
    findings: list
    suppressed: int = 0
    baselined: int = 0
    files_scanned: int = 0
    inventory: dict | None = None    # generated site inventory

    @property
    def failures(self) -> list:
        return [f for f in self.findings
                if not f.suppressed and not f.baselined]


# ---------------------------------------------------------------- helpers

def _unparse(node) -> str:
    try:
        return ast.unparse(node)
    except Exception:               # pragma: no cover - defensive
        return ""


def _const_str(node) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _fstring_site(node) -> list[str] | None:
    """Expand an f-string fail-site argument over the known svc_kinds:
    ``f"{self.svc_kind}.txn"`` -> ["mds.txn", "ost.txn"].  Returns None
    when the argument is not a JoinedStr."""
    if not isinstance(node, ast.JoinedStr):
        return None
    parts = []
    for v in node.values:
        if isinstance(v, ast.Constant):
            parts.append([str(v.value)])
        else:                        # a {expr}: expand over svc kinds
            parts.append(list(SVC_KINDS))
    out = [""]
    for p in parts:
        out = [o + x for o in out for x in p]
    return out


class _FuncFacts:
    """Everything rule evaluation needs to know about one function."""

    def __init__(self, cls: str, name: str, node: ast.FunctionDef):
        self.cls = cls
        self.name = name
        self.node = node
        self.symbol = f"{cls}.{name}" if cls else name
        self.lineno = node.lineno
        self.transno_exprs: list[tuple[int, ast.expr]] = []
        self.txn_open_lines: list[int] = []
        self.emit_calls: list[tuple[int, ast.Call, ast.stmt]] = []
        self.llog_add_calls: list[int] = []
        self.retracted_vars: set[str] = set()     # retract(x) inside nested defs
        self.rpc_calls: list[int] = []            # .request( callsites
        self.self_calls: list[tuple[int, str]] = []  # self.method() calls
        self.lock_mut_lines: list[int] = []       # res.granted/.waiting mutation
        self.mentions_replay = False
        self.returns_emit = False                 # forwarding emit wrapper


class _ModuleScan(ast.NodeVisitor):
    def __init__(self, path: Path, tree: ast.Module):
        self.path = path
        self.funcs: list[_FuncFacts] = []
        self.op_regs: list[tuple[str, int, str, str]] = []  # cls,line,op,handler
        self.aliases: list[tuple[str, str, str]] = []       # cls, new, old attr
        self.fail_sites_registered: list[tuple[int, str, str]] = []
        self.fail_callsites: list[tuple[int, str, object]] = []  # line,kind,arg
        self.class_svc_kind: dict[str, str] = {}
        self._cls_stack: list[str] = []
        self._fn_stack: list[_FuncFacts] = []
        self.visit(tree)

    # ------------------------------------------------------------ scoping
    @property
    def _cls(self) -> str:
        return self._cls_stack[-1] if self._cls_stack else ""

    @property
    def _fn(self) -> _FuncFacts | None:
        return self._fn_stack[-1] if self._fn_stack else None

    def visit_ClassDef(self, node: ast.ClassDef):
        self._cls_stack.append(node.name)
        self.generic_visit(node)
        self._cls_stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef):
        if self._fn_stack:
            # nested function (an undo closure): stay attributed to the
            # enclosing handler but remember retract targets
            self.generic_visit(node)
            return
        ff = _FuncFacts(self._cls, node.name, node)
        self.funcs.append(ff)
        self._fn_stack.append(ff)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -------------------------------------------------------------- facts
    def visit_Assign(self, node: ast.Assign):
        fn = self._fn
        # handler-table registration: <ops-expr>["name"] = self.op_x
        for tgt in node.targets:
            if (isinstance(tgt, ast.Subscript)
                    and "ops" in _unparse(tgt.value).split(".")[-1:]):
                op = _const_str(tgt.slice)
                if op is not None:
                    handler = _unparse(node.value)
                    self.op_regs.append((self._cls, node.lineno, op, handler))
            # rep.transno = <expr> (a Reply being given a transno; bare
            # self.transno/req.transno bookkeeping is not a reply)
            if (isinstance(tgt, ast.Attribute) and tgt.attr == "transno"
                    and fn is not None
                    and not (isinstance(tgt.value, ast.Name)
                             and tgt.value.id in ("self", "req"))):
                fn.transno_exprs.append((node.lineno, node.value))
            # lock-state mutation by assignment: res.granted = [...]
            if isinstance(tgt, ast.Attribute) and tgt.attr in (
                    "granted", "waiting") and fn is not None:
                fn.lock_mut_lines.append(node.lineno)
        # emit assigned to a variable: clrec = self._cl(...) handled in
        # the rule pass via emit_calls carrying the statement node.
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        fn = self._fn
        func_src = _unparse(node.func)
        attr = node.func.attr if isinstance(node.func, ast.Attribute) else (
            node.func.id if isinstance(node.func, ast.Name) else "")

        # ---- fail-site registry + callsites
        if attr == "register_site" and node.args:
            name = _const_str(node.args[0])
            desc = _const_str(node.args[1]) if len(node.args) > 1 else ""
            if name:
                self.fail_sites_registered.append(
                    (node.lineno, name, desc or ""))
        if attr in ("maybe_fail", "note", "check", "defer") and node.args \
                and ("fail" in func_src or func_src.startswith("state.")):
            self.fail_callsites.append((node.lineno, attr, node.args[0]))

        if fn is not None:
            # ---- transno keyword on a Reply(...) construction
            if attr.endswith("Reply"):
                for kw in node.keywords:
                    if kw.arg == "transno" and not (
                            isinstance(kw.value, ast.Constant)
                            and kw.value.value == 0):
                        fn.transno_exprs.append((node.lineno, kw.value))
            # ---- txn-opening calls
            if attr in ("txn", "txn_meta") and func_src.startswith("self."):
                fn.txn_open_lines.append(node.lineno)
            if ".obd." in func_src and attr in OBD_MUTATORS:
                fn.txn_open_lines.append(node.lineno)
            if attr == "_wrap" and node.args:
                first = _unparse(node.args[0])
                if ".obd." in first and first.rsplit(".", 1)[-1] in \
                        OBD_MUTATORS:
                    fn.txn_open_lines.append(node.lineno)
            if ".changelog." in func_src and attr in CHANGELOG_TXN_METHODS:
                fn.txn_open_lines.append(node.lineno)
            # ---- emit / llog-write sites
            if attr == "emit" and "changelog" in func_src:
                fn.emit_calls.append((node.lineno, node, None))
            if attr == "add" and ("catalog" in func_src
                                  or "llog" in func_src):
                fn.llog_add_calls.append(node.lineno)
            if attr == "retract":
                for a in node.args:
                    if isinstance(a, ast.Name):
                        fn.retracted_vars.add(a.id)
            # ---- RPC + self-call + lock-mutation facts
            if attr == "request":
                fn.rpc_calls.append(node.lineno)
            if isinstance(node.func, ast.Attribute) and isinstance(
                    node.func.value, ast.Name) and \
                    node.func.value.id == "self":
                fn.self_calls.append((node.lineno, attr))
            if attr in ("append", "remove", "insert", "pop", "clear") and \
                    isinstance(node.func, ast.Attribute) and isinstance(
                        node.func.value, ast.Attribute) and \
                    node.func.value.attr in ("granted", "waiting"):
                fn.lock_mut_lines.append(node.lineno)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        fn = self._fn
        if fn is not None and node.attr == "replay":
            fn.mentions_replay = True
        self.generic_visit(node)

    # class attribute svc_kind = "..."
    def visit_Module(self, node):              # pragma: no cover - unused
        self.generic_visit(node)


def _scan_class_meta(scan: _ModuleScan, tree: ast.Module):
    scan.class_aliases = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for stmt in node.body:
                if not (isinstance(stmt, ast.Assign) and stmt.targets
                        and isinstance(stmt.targets[0], ast.Name)):
                    continue
                tname = stmt.targets[0].id
                if tname == "svc_kind":
                    v = _const_str(stmt.value)
                    if v:
                        scan.class_svc_kind[node.name] = v
                # class-level method alias: op_remote_create = op_remote_mkdir
                elif isinstance(stmt.value, ast.Name):
                    scan.class_aliases[(node.name, tname)] = stmt.value.id


# ---------------------------------------------------------------- comments

def _scan_comments(src: str):
    """Per-line suppressions and rpc-under-lock annotations.  A marker on
    a comment-only line (or block of them) also covers the next code
    line, so multi-line reason comments can precede the statement."""
    suppress: dict[int, set[str]] = {}
    annotate: set[int] = set()
    carry_sup: set[str] = set()
    carry_ann = False
    for i, line in enumerate(src.splitlines(), 1):
        m = _SUPPRESS_RE.search(line)
        rules = {r.strip() for r in m.group(1).split(",")
                 if r.strip()} if m else set()
        ann = bool(_ANNOT_RE.search(line))
        if rules:
            suppress.setdefault(i, set()).update(rules)
        if ann:
            annotate.add(i)
        stripped = line.strip()
        if stripped.startswith("#"):
            carry_sup |= rules
            carry_ann = carry_ann or ann
        elif stripped:
            if carry_sup:
                suppress.setdefault(i, set()).update(carry_sup)
            if carry_ann:
                annotate.add(i)
            carry_sup, carry_ann = set(), False
    return suppress, annotate


# ------------------------------------------------------------------ driver

class _FileCtx:
    def __init__(self, path: Path, display: str):
        self.path = path
        self.display = display
        src = path.read_text()
        self.tree = ast.parse(src)
        self.scan = _ModuleScan(path, self.tree)
        _scan_class_meta(self.scan, self.tree)
        self.suppress, self.annotate = _scan_comments(src)
        # map line -> enclosing top-level function (for def-line suppress)
        self.func_of_line: dict[int, _FuncFacts] = {}
        for ff in self.scan.funcs:
            end = getattr(ff.node, "end_lineno", ff.lineno)
            for ln in range(ff.lineno, end + 1):
                self.func_of_line[ln] = ff


def _collect_files(paths: list[Path]) -> list[Path]:
    out = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            out.append(p)
            continue
        for f in sorted(p.rglob("*.py")):
            posix = f.as_posix()
            if "repro/core/" in posix or "repro/fsio/" in posix:
                out.append(f)
    return out


def _display(path: Path) -> str:
    posix = path.as_posix()
    for marker in ("src/repro/", "repro/"):
        idx = posix.find(marker)
        if idx >= 0:
            return posix[idx:]
    return posix


class Linter:
    def __init__(self, paths: list[Path], *, inventory_path: Path,
                 matrix_path: Path | None, baseline_path: Path | None):
        self.files = [_FileCtx(p, _display(p))
                      for p in _collect_files(paths)]
        self.inventory_path = inventory_path
        self.matrix_path = matrix_path
        self.baseline = self._load_baseline(baseline_path)
        self.findings: list[Finding] = []
        self.inventory: dict = {}

    # -------------------------------------------------------------- infra
    @staticmethod
    def _load_baseline(path: Path | None) -> list[dict]:
        if path is None or not path.exists():
            return []
        data = json.loads(path.read_text())
        return data.get("known_issues", data if isinstance(data, list) else [])

    def _emit(self, ctx: _FileCtx, rule: str, line: int, msg: str,
              symbol: str = ""):
        f = Finding(rule, ctx.display, line, msg, symbol)
        sup = ctx.suppress.get(line, set())
        ff = ctx.func_of_line.get(line)
        if ff is not None:
            sup = sup | ctx.suppress.get(ff.lineno, set())
            if not symbol:
                f.symbol = ff.symbol
        if rule in sup or "all" in sup:
            f.suppressed = True
        elif any(b.get("rule") == rule
                 and ctx.display.endswith(b.get("path", "\x00"))
                 and (not b.get("symbol") or b["symbol"] == f.symbol)
                 for b in self.baseline):
            f.baselined = True
        self.findings.append(f)

    # --------------------------------------------------------------- run
    def run(self) -> LintResult:
        self.rule_txn_scope()
        self.rule_emit_in_txn()
        self.rule_fail_site()
        self.rule_replay_coverage()
        self.rule_rpc_under_lock()
        res = LintResult(findings=self.findings,
                         suppressed=sum(f.suppressed for f in self.findings),
                         baselined=sum(f.baselined for f in self.findings),
                         files_scanned=len(self.files),
                         inventory=self.inventory)
        return res

    # ----------------------------------------------------- rule: txn-scope
    HANDLER_RE = re.compile(r"^(op_|_reint_|_intent_)")

    @staticmethod
    def _delegated_transno(expr: ast.expr) -> bool:
        """A transno that came out of another call's result (peer reply,
        backend out["transno"], intent _transno) — the transaction was
        opened by the callee, not this handler."""
        if isinstance(expr, ast.Subscript):
            return True
        src = _unparse(expr)
        return src.startswith(("self.txn(", "self.txn_meta("))

    def rule_txn_scope(self):
        for ctx in self.files:
            for ff in ctx.scan.funcs:
                if not self.HANDLER_RE.match(ff.name):
                    continue
                if not ff.transno_exprs:
                    continue                     # read-only handler
                if ff.txn_open_lines:
                    continue                     # opened a txn scope
                bad = []
                for line, expr in ff.transno_exprs:
                    if self._delegated_transno(expr):
                        continue
                    if _unparse(expr) == "self.transno" and \
                            ff.mentions_replay:
                        continue                 # replay-idempotent return
                    bad.append((line, _unparse(expr)))
                for line, src in bad:
                    self._emit(ctx, "txn-scope", line,
                               f"handler {ff.symbol} returns "
                               f"transno={src} without opening a txn "
                               f"undo scope (self.txn/self.txn_meta/"
                               f"obd mutator)", ff.symbol)

    # --------------------------------------------------- rule: emit-in-txn
    def rule_emit_in_txn(self):
        # pass 1: find forwarding wrappers (return self.changelog.emit(..))
        forwarders: set[str] = set()
        for ctx in self.files:
            for ff in ctx.scan.funcs:
                for node in ast.walk(ff.node):
                    if isinstance(node, ast.Return) and isinstance(
                            node.value, ast.Call):
                        src = _unparse(node.value.func)
                        if src.endswith("changelog.emit"):
                            forwarders.add(ff.name)
                            ff.returns_emit = True
        # pass 2: check every emit site (direct or through a forwarder)
        for ctx in self.files:
            if ctx.path.name in EMIT_IMPL_MODULES:
                continue
            for ff in ctx.scan.funcs:
                for stmt in ast.walk(ff.node):
                    if not isinstance(stmt, (ast.Assign, ast.Expr,
                                             ast.Return)):
                        continue
                    call = stmt.value if isinstance(
                        getattr(stmt, "value", None), ast.Call) else None
                    if call is None:
                        continue
                    src = _unparse(call.func)
                    attr = src.rsplit(".", 1)[-1]
                    is_emit = (attr == "emit" and "changelog" in src) or \
                        (attr in forwarders and src.startswith("self."))
                    if not is_emit:
                        continue
                    line = call.lineno
                    if isinstance(stmt, ast.Return):
                        if ff.returns_emit:
                            continue             # the wrapper itself
                        self._emit(ctx, "emit-in-txn", line,
                                   f"{ff.symbol} returns a changelog "
                                   f"record it never retracts in a txn "
                                   f"undo", ff.symbol)
                        continue
                    if isinstance(stmt, ast.Expr):
                        self._emit(ctx, "emit-in-txn", line,
                                   f"{ff.symbol} discards the emitted "
                                   f"changelog record — an aborted txn "
                                   f"could not retract it", ff.symbol)
                        continue
                    tgt = stmt.targets[0]
                    var = tgt.id if isinstance(tgt, ast.Name) else None
                    if var is None or var not in ff.retracted_vars:
                        self._emit(ctx, "emit-in-txn", line,
                                   f"{ff.symbol} emits a changelog record "
                                   f"({var or _unparse(tgt)}) with no "
                                   f"changelog.retract({var or '...'}) in "
                                   f"a registered undo closure", ff.symbol)
                        continue
                    if not any(t >= line for t in ff.txn_open_lines):
                        self._emit(ctx, "emit-in-txn", line,
                                   f"{ff.symbol} emits a changelog record "
                                   f"but opens no transaction after the "
                                   f"emit (txn/txn_meta)", ff.symbol)
                # llog writes outside the implementation layer
                for line in ff.llog_add_calls:
                    if not ff.txn_open_lines:
                        self._emit(ctx, "emit-in-txn", line,
                                   f"{ff.symbol} appends an llog record "
                                   f"outside any transaction scope",
                                   ff.symbol)

    # ----------------------------------------------------- rule: fail-site
    def rule_fail_site(self):
        registry: dict[str, dict] = {}
        callsites: dict[str, list] = {}
        reg_ctx = None
        for ctx in self.files:
            for line, name, desc in ctx.scan.fail_sites_registered:
                registry[name] = {"desc": desc, "line": line,
                                  "file": ctx.display}
                reg_ctx = ctx
        for ctx in self.files:
            for line, kind, arg in ctx.scan.fail_callsites:
                lit = _const_str(arg)
                names = [lit] if lit is not None else _fstring_site(arg)
                if names is None:
                    continue                     # dynamic, not checkable
                matched = [n for n in names if n in registry]
                if lit is not None and not matched:
                    self._emit(ctx, "fail-site", line,
                               f"OBD_FAIL callsite {kind}({lit!r}) names "
                               f"a site not registered in core/fail.py")
                    continue
                if lit is None and not matched:
                    self._emit(ctx, "fail-site", line,
                               f"OBD_FAIL f-string callsite ({kind}) "
                               f"expands to no registered site: {names}")
                    continue
                for n in matched:
                    callsites.setdefault(n, []).append(
                        {"file": ctx.display, "line": line, "kind": kind})
        for name, info in sorted(registry.items()):
            if name not in callsites:
                ctx = reg_ctx or self.files[0]
                self._emit(ctx, "fail-site", info["line"],
                           f"registered OBD_FAIL site {name!r} has no "
                           f"checkpoint callsite (dead site)")
        # ---- the machine-readable inventory the crash sweep consumes
        flavor_rank = {"check": 3, "defer": 3, "note": 2, "maybe_fail": 1}
        flavor_name = {3: "check", 2: "deferred", 1: "immediate"}
        inv_sites = {}
        for name, info in sorted(registry.items()):
            calls = callsites.get(name, [])
            rank = max((flavor_rank[c["kind"]] for c in calls), default=1)
            client_side = any(
                c["file"].endswith(("osc.py", "mdc.py", "client.py"))
                for c in calls)
            inv_sites[name] = {
                "desc": info["desc"],
                "flavor": flavor_name[rank],
                "side": "client" if client_side else "server",
                "callsites": sorted(f"{c['file']}:{c['line']}"
                                    for c in calls),
            }
        self.inventory = {"format": 1, "tool": "repro.tools.lint",
                          "sites": inv_sites}
        # ---- fail-sweep: committed inventory must match exactly
        ctx = reg_ctx or (self.files[0] if self.files else None)
        if ctx is None:
            return
        committed = load_inventory(self.inventory_path)
        if committed is None:
            self._emit(ctx, "fail-sweep", 1,
                       f"no site inventory at {self.inventory_path} — "
                       f"the crash sweep has nothing to parametrize "
                       f"over (run --write-inventory)")
            return
        have = set(committed.get("sites", {}))
        want = set(inv_sites)
        for name in sorted(want - have):
            self._emit(ctx, "fail-sweep", registry[name]["line"],
                       f"site {name!r} is registered but missing from "
                       f"the sweep inventory ({self.inventory_path.name})"
                       f" — unswept; run --write-inventory")
        for name in sorted(have - want):
            self._emit(ctx, "fail-sweep", 1,
                       f"inventory lists {name!r} which is no longer a "
                       f"registered site — stale; run --write-inventory")
        for name in sorted(want & have):
            if committed["sites"][name].get("flavor") != \
                    inv_sites[name]["flavor"]:
                self._emit(ctx, "fail-sweep", registry[name]["line"],
                           f"site {name!r} changed flavor "
                           f"({committed['sites'][name].get('flavor')} -> "
                           f"{inv_sites[name]['flavor']}); run "
                           f"--write-inventory")

    # ----------------------------------------- rule: replay-coverage
    def _load_matrix(self) -> dict | None:
        if self.matrix_path is None or not self.matrix_path.exists():
            return None
        ns: dict = {}
        exec(compile(self.matrix_path.read_text(),
                     str(self.matrix_path), "exec"), ns)
        return ns.get("REPLAY_MATRIX")

    def rule_replay_coverage(self):
        matrix = self._load_matrix()
        funcs_by_symbol = {}
        for ctx in self.files:
            for ff in ctx.scan.funcs:
                funcs_by_symbol[ff.symbol] = ff
        seen: set[tuple[str, str]] = set()
        for ctx in self.files:
            aliases = getattr(ctx.scan, "class_aliases", {})
            for cls, line, op, handler in ctx.scan.op_regs:
                seen.add((cls, op))
                m = re.match(r"self\.(\w+)$", handler)
                hname = m.group(1) if m else None
                for _ in range(4):           # resolve class-level aliases
                    if hname and (cls, hname) in aliases:
                        hname = aliases[(cls, hname)]
                ff = funcs_by_symbol.get(f"{cls}.{hname}") if hname else None
                covered = bool(ff and ff.transno_exprs)
                if covered:
                    continue                 # reply-cache-covered update op
                entry = (matrix or {}).get(cls, {}).get(op)
                if entry is None:
                    where = (f"{self.matrix_path}" if self.matrix_path
                             else "tests/replay_matrix.py")
                    self._emit(ctx, "replay-coverage", line,
                               f"op {op!r} ({cls}) bears no transno (not "
                               f"reply-cache-covered) and is missing from "
                               f"the replay-idempotence matrix ({where})",
                               f"{cls}.{op}")
        # stale matrix entries (op no longer registered) drift silently
        if matrix and self.files:
            ctx = self.files[0]
            for cls, ops in matrix.items():
                for op in ops:
                    if (cls, op) not in seen:
                        self._emit(ctx, "replay-coverage", 1,
                                   f"replay matrix lists {cls}.{op} which "
                                   f"is not registered in any handler "
                                   f"table (stale entry)", f"{cls}.{op}")

    # ------------------------------------------------- rule: rpc-under-lock
    def rule_rpc_under_lock(self):
        # per-class transitive closure of rpc-issuing methods
        rpc_methods: set[str] = set()
        by_cls: dict[str, list[_FuncFacts]] = {}
        for ctx in self.files:
            for ff in ctx.scan.funcs:
                by_cls.setdefault(ff.cls, []).append(ff)
                if ff.rpc_calls:
                    rpc_methods.add(ff.symbol)
        changed = True
        while changed:
            changed = False
            for cls, ffs in by_cls.items():
                for ff in ffs:
                    if ff.symbol in rpc_methods:
                        continue
                    if any(f"{cls}.{callee}" in rpc_methods
                           for _, callee in ff.self_calls):
                        rpc_methods.add(ff.symbol)
                        changed = True
        for ctx in self.files:
            for ff in ctx.scan.funcs:
                if not ff.lock_mut_lines:
                    continue
                first_mut = min(ff.lock_mut_lines)
                risky = [(ln, "request") for ln in ff.rpc_calls
                         if ln > first_mut]
                risky += [(ln, callee) for ln, callee in ff.self_calls
                          if ln > first_mut
                          and f"{ff.cls}.{callee}" in rpc_methods]
                for line, what in sorted(risky):
                    if line in ctx.annotate or ff.lineno in ctx.annotate:
                        continue
                    self._emit(ctx, "rpc-under-lock", line,
                               f"{ff.symbol} issues an RPC ({what}) while "
                               f"a local DLM resource is mid-transition "
                               f"(mutated at line {first_mut}); annotate "
                               f"with '# lint: rpc-under-lock(reason)' if "
                               f"the ordering is deadlock-safe", ff.symbol)


# -------------------------------------------------------------- inventory

def load_inventory(path: Path | str = INVENTORY_PATH) -> dict | None:
    path = Path(path)
    if not path.exists():
        return None
    return json.loads(path.read_text())


def write_inventory(inventory: dict, path: Path | str = INVENTORY_PATH):
    Path(path).write_text(json.dumps(inventory, indent=1, sort_keys=True)
                          + "\n")


# ------------------------------------------------------------------ entry

def run_lint(paths: list, *, inventory_path=INVENTORY_PATH,
             matrix_path=None, baseline_path=BASELINE_PATH) -> LintResult:
    paths = [Path(p) for p in paths]
    if matrix_path is None:
        # default: <repo>/tests/replay_matrix.py relative to the scanned
        # tree (src/.. or the tree root itself)
        for p in paths:
            for cand in (p.parent / "tests" / "replay_matrix.py",
                         p / "tests" / "replay_matrix.py"):
                if cand.exists():
                    matrix_path = cand
                    break
    linter = Linter(paths, inventory_path=Path(inventory_path),
                    matrix_path=Path(matrix_path) if matrix_path else None,
                    baseline_path=Path(baseline_path)
                    if baseline_path else None)
    return linter.run()
