"""Sharded token data pipeline over the Lustre substrate.

The training corpus is one big token file striped over all OSTs; every
data-parallel rank reads its own deterministic slice per step. Reads go
through the collaborative cache (COBD, §5.5) when caching nodes are
registered — the "cluster boots and everyone reads the same file" pattern
the paper built the COBD for. Determinism: (seed, epoch) -> a stable
permutation of sequence indices, sharded by rank, so restarts resume
exactly (the trainer checkpoints `step`).
"""
from __future__ import annotations

import numpy as np

from repro.fsio.client import LustreClient


class TokenDataset:
    """Writer/creator for a token corpus file."""

    def __init__(self, fs: LustreClient, path: str = "/data/tokens.bin",
                 *, vocab: int = 32000, seq_len: int = 128,
                 n_seqs: int = 1024, seed: int = 0,
                 stripe_count: int = 0, stripe_size: int = 1 << 20):
        self.fs = fs
        self.path = path
        self.vocab = vocab
        self.seq_len = seq_len
        self.n_seqs = n_seqs
        self.seed = seed
        self.stripe_count = stripe_count
        self.stripe_size = stripe_size

    @property
    def seq_bytes(self) -> int:
        return self.seq_len * 4

    def build(self) -> "TokenDataset":
        """Generate + write the corpus (idempotent)."""
        if self.fs.exists(self.path):
            return self
        parent = "/".join(p for p in self.path.split("/")[:-1] if p)
        if parent:
            self.fs.mkdir_p(parent)
        rng = np.random.default_rng(self.seed)
        fh = self.fs.creat(self.path, stripe_count=self.stripe_count,
                           stripe_size=self.stripe_size)
        chunk = 256
        for start in range(0, self.n_seqs, chunk):
            n = min(chunk, self.n_seqs - start)
            toks = rng.integers(0, self.vocab, size=(n, self.seq_len),
                                dtype=np.int32)
            self.fs.write(fh, toks.tobytes(), offset=start * self.seq_bytes)
        self.fs.close(fh)
        return self


class TokenPipeline:
    """Deterministic per-rank batch iterator reading striped data."""

    def __init__(self, fs: LustreClient, ds: TokenDataset, *,
                 dp_rank: int, dp_size: int, batch_per_rank: int,
                 seed: int = 1234):
        self.fs = fs
        self.ds = ds
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.batch = batch_per_rank
        self.seed = seed
        self.fh = fs.open(ds.path, "r")
        self.per_epoch = ds.n_seqs // (dp_size * batch_per_rank)

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.ds.n_seqs)

    def indices_for(self, step: int) -> np.ndarray:
        epoch, within = divmod(step, self.per_epoch)
        perm = self._perm(epoch)
        base = within * self.dp_size * self.batch
        mine = perm[base + self.dp_rank * self.batch:
                    base + (self.dp_rank + 1) * self.batch]
        return np.sort(mine)

    def batch_at(self, step: int) -> np.ndarray:
        """(batch, seq_len) int32 tokens for this rank at `step`."""
        idx = self.indices_for(step)
        sb = self.ds.seq_bytes
        out = np.empty((self.batch, self.ds.seq_len), np.int32)
        # coalesce adjacent sequences into one striped read
        runs = []
        run_start = idx[0]
        prev = idx[0]
        for i in idx[1:]:
            if i != prev + 1:
                runs.append((run_start, prev))
                run_start = i
            prev = i
        runs.append((run_start, prev))
        row = 0
        for a, b in runs:
            data = self.fs.read(self.fh, (b - a + 1) * sb, offset=a * sb)
            arr = np.frombuffer(data, np.int32).reshape(-1, self.ds.seq_len)
            out[row:row + len(arr)] = arr
            row += len(arr)
        return out

    def close(self):
        self.fs.close(self.fh)
