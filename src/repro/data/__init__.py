"""Sharded token data pipeline."""
from repro.data.pipeline import TokenDataset, TokenPipeline  # noqa: F401
