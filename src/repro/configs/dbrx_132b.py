"""DBRX — 132B MoE, 16 experts top-4, fine-grained
[hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="transformer", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=10752, vocab=100352,
    rope_theta=5e5, n_experts=16, top_k=4, d_ff_expert=10752, act="silu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=256, n_experts=4,
                      top_k=2, d_ff_expert=128)
