"""Per-architecture configs (exact public configs; see inline citations)."""
from __future__ import annotations

import importlib

ARCHS = [
    "yi-9b", "gemma3-12b", "qwen3-4b", "qwen2-7b", "paligemma-3b",
    "phi3.5-moe", "dbrx-132b", "rwkv6-3b", "whisper-tiny", "zamba2-7b",
]

_MOD = {
    "yi-9b": "yi_9b", "gemma3-12b": "gemma3_12b", "qwen3-4b": "qwen3_4b",
    "qwen2-7b": "qwen2_7b", "paligemma-3b": "paligemma_3b",
    "phi3.5-moe": "phi35_moe", "dbrx-132b": "dbrx_132b",
    "rwkv6-3b": "rwkv6_3b", "whisper-tiny": "whisper_tiny",
    "zamba2-7b": "zamba2_7b",
}


def get_config(name: str):
    return importlib.import_module(f"repro.configs.{_MOD[name]}").CONFIG


def get_smoke_config(name: str):
    return importlib.import_module(f"repro.configs.{_MOD[name]}").SMOKE
