"""Zamba2-7B — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. Per-invocation LoRA on the shared block omitted."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="zamba2", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    rope_theta=1e4, ssm_state=64, d_inner=7168, ssm_head_dim=64,
    attn_every=6, act="gelu")

SMOKE = CONFIG.scaled(n_layers=13, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab=256, ssm_state=16,
                      d_inner=128, ssm_head_dim=16)
