"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv6", n_layers=32, d_model=2560,
    d_ff=8960, vocab=65536, rwkv_head_dim=64)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=128, vocab=256,
                      rwkv_head_dim=16)
