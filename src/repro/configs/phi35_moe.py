"""Phi-3.5-MoE — 16 experts top-2, 42B total / 6.6B active
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe", family="transformer", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=6400, vocab=32064,
    rope_theta=1e4, n_experts=16, top_k=2, d_ff_expert=6400, act="silu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=256, n_experts=4,
                      top_k=2, d_ff_expert=128)
