"""Qwen2-7B — dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="transformer", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18944, vocab=152064,
    rope_theta=1e6, qkv_bias=True, act="silu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=256)
