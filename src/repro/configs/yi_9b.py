"""Yi-9B — llama-arch dense GQA [arXiv:2403.04652; hf:01-ai/Yi-9B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="transformer", n_layers=48, d_model=4096,
    n_heads=32, n_kv_heads=4, head_dim=128, d_ff=11008, vocab=64000,
    rope_theta=5e6, act="silu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=256)
