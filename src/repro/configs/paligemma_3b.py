"""PaliGemma-3B — gemma decoder + SigLIP patch-prefix (stub frontend)
[arXiv:2407.07726]. Patch embeddings arrive precomputed at d_model."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="transformer", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab=257216,
    rope_theta=1e4, n_patches=256, act="gelu", embed_scale=True)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                      head_dim=16, d_ff=128, vocab=256, n_patches=8)
