"""Qwen3-4B — dense GQA with qk-norm [hf:Qwen/Qwen3-4B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="transformer", n_layers=36, d_model=2560,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728, vocab=151936,
    rope_theta=1e6, qk_norm=True, act="silu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=256)
