"""Gemma3-12B — 5:1 local:global attention, qk-norm, 256k vocab
[hf:google/gemma-3-12b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="transformer", n_layers=48, d_model=3840,
    n_heads=16, n_kv_heads=8, head_dim=256, d_ff=15360, vocab=262144,
    rope_theta=1e6, sliding_window=1024, global_every=6, qk_norm=True,
    act="gelu", embed_scale=True)

SMOKE = CONFIG.scaled(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=256, sliding_window=8)
