"""Whisper-tiny — enc-dec audio transformer; conv frontend is a stub
(input_specs provides 1500 precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="transformer", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, head_dim=64, d_ff=1536, vocab=51865,
    rope_theta=0.0, enc_layers=4, enc_frames=1500, act="gelu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab=256, enc_layers=2,
                      enc_frames=16)
