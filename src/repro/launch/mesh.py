"""Production mesh construction.

Called as a FUNCTION so importing this module never touches jax device
state. Single pod = 256 chips (16, 16) ("data", "model"); multi-pod adds a
leading "pod" axis (outer data parallelism whose gradient all-reduce crosses
pods on DCN).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (CPU smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~ per-direction)
HBM_BYTES = 16e9              # capacity
