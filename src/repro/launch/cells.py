"""The 40 assigned (architecture x shape) cells and skip rules."""
from __future__ import annotations

from repro.configs import ARCHS
from repro.models.config import SHAPES

# Per-cell RunConfig overrides (baseline must FIT the 16 GB/chip HBM):
# MoE dispatch buffers scale with microbatch tokens -> more accumulation
# steps for the MoE giants.
OVERRIDES = {
    ("phi3.5-moe", "train_4k"): {"num_microbatches": 16,
                                 "shard_moe_tokens": True},
    ("dbrx-132b", "train_4k"): {"num_microbatches": 16,
                                "shard_moe_tokens": True},
    ("phi3.5-moe", "prefill_32k"): {"shard_moe_tokens": True},
    ("dbrx-132b", "prefill_32k"): {"shard_moe_tokens": True},
    ("phi3.5-moe", "decode_32k"): {"shard_moe_tokens": True},
    ("dbrx-132b", "decode_32k"): {"shard_moe_tokens": True},
    # ring-buffered local caches for the 5:1 local:global mix (§Perf)
    ("gemma3-12b", "decode_32k"): {"windowed_cache": True},
    ("gemma3-12b", "long_500k"): {"windowed_cache": True},
}
# (chunked_ce overrides were tried for the big-vocab train cells and
# REFUTED: logits are already vocab+batch sharded, the peak is the remat
# residual stack — see EXPERIMENTS.md §Perf)

# long_500k needs sub-quadratic attention: runs for SSM/hybrid and for
# gemma3 (5:1 local:global — decode cost is linear per token); skipped for
# pure full-attention archs (see DESIGN.md §3).
LONG_OK = {"rwkv6-3b", "zamba2-7b", "gemma3-12b"}

SKIP = {}
for _a in ARCHS:
    if _a not in LONG_OK:
        SKIP[(_a, "long_500k")] = (
            "full quadratic attention at 524k context (no sub-quadratic "
            "path in this family); see DESIGN.md §3")


def cells(include_skipped: bool = False):
    for arch in ARCHS:
        for shape in SHAPES:
            if (arch, shape) in SKIP and not include_skipped:
                continue
            yield arch, shape


def cell_skips():
    return dict(SKIP)
