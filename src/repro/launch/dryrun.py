import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh with ShapeDtypeStruct inputs (no allocation), print
memory/cost analysis, and derive the roofline terms from the compiled HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import get_config
from repro.launch import cells as cells_mod
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES
from repro.parallel import shardings as sh
from repro.tools import hlo_cost, roofline
from repro.train import steps as steps_mod


def run_cell(arch: str, shape: str, multi_pod: bool, rc_overrides=None,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    rc = SHAPES[shape]
    base_over = cells_mod.OVERRIDES.get((arch, shape))
    if base_over:
        rc = dataclasses.replace(rc, **base_over)
    if rc_overrides:
        rc = dataclasses.replace(rc, **rc_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    sh.set_ambient_mesh(mesh)
    t0 = time.time()
    bundle = steps_mod.build_step(cfg, rc, mesh)
    with mesh:
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    report = hlo_cost.analyze_compiled(compiled)
    roof = roofline.compute(report, cfg, rc, n_chips)
    out = {
        "arch": arch, "shape": shape, "kind": rc.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "xla_cost_analysis": {
            "flops": cost.get("flops"),
            "bytes accessed": cost.get("bytes accessed"),
        },
        "hlo_cost": report.as_dict(),
        "roofline": roof.as_dict(),
    }
    if verbose:
        _print_cell(out, mem)
    return out


def _print_cell(out, mem):
    r = out["roofline"]
    h = out["hlo_cost"]
    print(f"== {out['arch']} x {out['shape']} on {out['mesh']} "
          f"({out['n_chips']} chips) ==")
    print(f"   lower {out['lower_s']}s  compile {out['compile_s']}s")
    print(f"   memory_analysis: {mem}")
    print(f"   per-device: flops {h['flops']:.3e}  hbm {h['traffic_bytes']:.3e}B  "
          f"collective {h['collective_bytes']:.3e}B  "
          f"({h['n_while']} while loops: {h['trip_counts']})")
    print(f"   collectives: "
          + ", ".join(f"{k}={v:.2e}B" for k, v in h["collectives"].items()))
    print(f"   roofline: compute {r['compute_s']*1e3:.2f}ms  "
          f"memory {r['memory_s']*1e3:.2f}ms  "
          f"collective {r['collective_s']*1e3:.2f}ms  "
          f"-> {r['dominant']}-bound;  "
          f"useful_flops_ratio {r['useful_ratio']:.3f}  "
          f"MFU-bound {r['mfu_bound']:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig override k=v (hillclimbing)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    todo = (list(cells_mod.cells()) if args.all
            else [(args.arch, args.shape)])
    failures = []
    for arch, shape in todo:
        tag = "2pod" if args.multi_pod else "1pod"
        suffix = ("_" + "_".join(f"{k}-{v}" for k, v in overrides.items())
                  if overrides else "")
        path = os.path.join(args.out, f"{arch}_{shape}_{tag}{suffix}.json")
        try:
            res = run_cell(arch, shape, args.multi_pod, overrides or None)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run OK ({len(todo)} cells, "
          f"{'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")


if __name__ == "__main__":
    main()
