"""Client filesystem API (Lustre Lite) + global namespace (ch. 3)."""
from repro.fsio.client import LustreClient, FsError, FileHandle  # noqa: F401
from repro.fsio.namespace import (Automounter, GlobalNamespace,  # noqa: F401
                                  make_mount_object)
