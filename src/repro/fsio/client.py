"""LustreClient: the client filesystem (paper ch. 9, 28 — "Lustre Lite").

POSIX-ish API over the LMV (metadata) + LOV (data) stacks:
  * path resolution with a *dentry cache* guarded by DLM locks — an entry is
    valid exactly while its PR lock is held; server-side updates revoke via
    blocking ASTs (ch. 28.4); negative entries are cached too (§6.2.1);
  * `open(path, "cw")` is ONE intent RPC doing lookup+create+open (§6.4.3);
    the client then creates the stripe objects and writes the LOV EA back
    (the MDS returned the new inode under a lock so only this client
    creates objects);
  * file I/O through LOV striping under extent locks, write-back cached
    with grants (ch. 10, 28.5);
  * size/mtime: while a file is open for write the OSTs own mtime/size;
    `close` ships them to the MDS (§6.9.1); `stat` consults the OSTs when
    the MDS flag says so — via batched glimpse ASTs that leave the
    writers' PW locks and caches intact (§7.7);
  * metadata read-path batching (ISSUE-5): readdir-plus paged scans
    (`dir_pages`), a fid-keyed attribute cache valid exactly while the
    covering DLM lock is held (revocation-invalidated like the dentry
    cache), and a statahead pipeline prefetching attr windows for
    sequential stat patterns (`statahead_max`);
  * optional metadata write-back-cache mode for create-heavy directories
    (ch. 17).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Optional

from repro.core import fail as fail_mod
from repro.core import lov as lov_mod
from repro.core import mdc as mdc_mod
from repro.core import osc as osc_mod
from repro.core import mds as mds_mod
from repro.core import ptlrpc as R
from repro.core import recovery as rec_mod
from repro.core.cluster import LustreCluster

ROOT = mds_mod.ROOT_FID


class FsError(Exception):
    def __init__(self, errno: int, msg: str = ""):
        super().__init__(f"errno {errno}: {msg}")
        self.errno = errno


@dataclasses.dataclass
class FileHandle:
    fid: tuple
    lsm: Optional[lov_mod.StripeMd]
    open_handle: int
    flags: str
    pos: int = 0
    max_written: int = 0
    mtime: float = 0.0
    # per-handle sequential-read detector state (readahead):
    ra_next: int = 0           # offset the next sequential read starts at
    ra_window: int = 0         # current readahead window (bytes, ramps up)
    ra_pos: int = 0            # how far readahead has already fetched
    # opened through the metadata write-back cache: no MDS open handle,
    # close() records size/mtime as a cache record instead of an RPC
    wbc: bool = False


@dataclasses.dataclass
class Dentry:
    fid: tuple | None            # None = negative entry
    attrs: dict | None
    lock_handle: int | None      # validity = lock still held
    # which Mdc's lock cache holds the covering lock (split-dir bucket
    # pages are covered by the BUCKET MDS's lock, not the master's);
    # None = the parent fid's own Mdc (the common case)
    mdc: object = None


@dataclasses.dataclass
class CachedAttr:
    """One fid's cached attributes (+EA), valid exactly while the
    covering DLM lock — the PR lock of a directory the inode is linked
    in — is still in `mdc`'s lock cache. The MDS revokes that lock on
    ANY attr change (setattr/close/open-for-write) via the inode's
    pfids, so validity mirrors the dentry cache (ISSUE-5)."""
    attrs: dict
    ea: dict
    mdc: object
    lock_handle: int


class _Statahead:
    """Per-directory sequential-stat detector: the metadata analogue of
    the PR 4 readahead detector. `order` is the last readdir(-plus)
    order; stats walking it in order ramp a prefetch window."""

    __slots__ = ("order", "index", "pos", "run", "fetched")

    def __init__(self, order):
        self.order = list(order)           # [(name, fid), ...]
        self.index = {n: i for i, (n, _) in enumerate(self.order)}
        self.pos = -1                      # index of the last stat
        self.run = 0                       # sequential-run length
        self.fetched = 0                   # prefetch horizon (index)


class LustreClient:
    def __init__(self, cluster: LustreCluster, node_idx: int = 0,
                 default_stripe_count: int = 0,
                 default_stripe_size: int = 1 << 20,
                 max_pages_per_rpc: int | None = None,
                 max_rpcs_in_flight: int | None = None,
                 vectored_brw: bool | None = None,
                 max_cached_mb: int | None = None,
                 readahead_pages: int | None = None,
                 dir_pages: int | None = None,
                 statahead_max: int | None = None,
                 wbc_auto: bool | None = None,
                 wbc_batch: int | None = None,
                 wbc_max_dirty: int | None = None):
        self.cluster = cluster
        self.rpc = cluster.make_client_rpc(node_idx)
        self.lmv = cluster.make_lmv(self.rpc)
        # BRW pipeline + read cache knobs: per-client override of the
        # cluster defaults
        osc_kw = {k: v for k, v in (
            ("max_pages_per_rpc", max_pages_per_rpc),
            ("max_rpcs_in_flight", max_rpcs_in_flight),
            ("vectored_brw", vectored_brw),
            ("max_cached_mb", max_cached_mb)) if v is not None}
        self.lov = cluster.make_lov(self.rpc, **osc_kw)
        self.readahead_pages = cluster.readahead_pages \
            if readahead_pages is None else readahead_pages
        # metadata read-path knobs (ISSUE-5): readdir-plus page size
        # (0 = per-entry seed path) + statahead prefetch window (0 off)
        self.dir_pages = cluster.dir_pages if dir_pages is None \
            else dir_pages
        self.statahead_max = cluster.statahead_max if statahead_max is None \
            else statahead_max
        # metadata write-back knobs (ISSUE-6): wbc_auto enters WBC mode
        # on the first metadata write under a directory (the §6.5.2
        # contention decision on the MDS still gets the final say);
        # wbc_batch/wbc_max_dirty drive the background flush pipeline
        self.wbc_auto = cluster.wbc_auto if wbc_auto is None else wbc_auto
        self.wbc_batch = cluster.wbc_batch if wbc_batch is None \
            else wbc_batch
        self.wbc_max_dirty = cluster.wbc_max_dirty if wbc_max_dirty is None \
            else wbc_max_dirty
        self.wbc_max_rpcs = cluster.max_rpcs_in_flight \
            if max_rpcs_in_flight is None else max_rpcs_in_flight
        self._wbc_denied: set = set()   # parents the MDS refused WBC for
        self.sim = cluster.sim
        # eviction by an MDS voids every lock that guards the dentry
        # cache: drop the locks (local-only) and the dentries with them;
        # lock revocation (AST/cancel) drops the attr-cache entries the
        # lock covered — same machinery as the OSC clean cache (PR 4)
        for mdc in self.lmv.mdcs:
            mdc.imp.evict_cbs.append(
                lambda m=mdc: self._on_mds_evicted(m))
            mdc.locks.revoke_cbs.append(
                lambda lk, m=mdc: self._attrs_revoked(m, lk))
        self.default_stripe_count = default_stripe_count or len(
            cluster.ost_targets)
        self.default_stripe_size = default_stripe_size
        self.dcache: dict[tuple, Dentry] = {}     # (parent, name) -> Dentry
        # fid-keyed attribute cache, validity tied to the covering DLM
        # lock exactly like the dentry cache (ISSUE-5 tentpole)
        self.attr_cache: dict[tuple, CachedAttr] = {}
        self._attr_by_lock: dict[tuple, set] = defaultdict(set)
        # statahead pipeline state: per-dir detectors + one-shot results
        # for entries no held lock covers (remote-MDT attrs, glimpses)
        self._sa: dict[tuple, _Statahead] = {}
        self._sa_attrs: dict[tuple, dict] = {}
        self._sa_glimpse: dict[tuple, dict] = {}
        # negative-entry windows (ISSUE-6): dir fid -> (page locks,
        # names seen) from a COMPLETE readdir-plus pass — while every
        # page lock survives, any other name is known absent (ENOENT
        # with zero RPCs)
        self._neg_win: dict[tuple, tuple[list, set]] = {}
        self._fh = itertools.count(1)
        self.handles: dict[int, FileHandle] = {}
        self.wbc: mdc_mod.WbcCache | None = None
        # active health plane (ISSUE-10): one pinger over every import;
        # a ping-detected OST death marks it inactive in the LOV (raid5
        # serves degraded with zero RPCs at the corpse) and a detected
        # restart triggers imperative recovery. Drive with pinger.tick()
        # — nothing ticks it implicitly.
        self.pinger = rec_mod.Pinger(
            [o.imp for o in self.lov.oscs + self.lov.spares]
            + [m.imp for m in self.lmv.mdcs],
            lov=self.lov)

    # ------------------------------------------------------------- mount
    def mount(self) -> "LustreClient":
        self.lmv.getattr(ROOT)
        return self

    # ------------------------------------------------------ path walking
    @staticmethod
    def _parts(path: str) -> list[str]:
        return [p for p in path.split("/") if p]

    def _dentry_valid(self, key, mdc) -> bool:
        d = self.dcache.get(key)
        if d is None:
            return False
        if d.lock_handle is None:
            return False
        owner = d.mdc if d.mdc is not None else mdc
        return d.lock_handle in owner.locks.locks

    def _lookup(self, parent: tuple, name: str) -> Dentry:
        key = (tuple(parent), name)
        mdc = self.lmv.mdc_for_fid(parent)
        if self._dentry_valid(key, mdc):
            self.sim.stats.count("fs.dcache_hit")
            return self.dcache[key]
        d = self._neg_lookup(key)
        if d is not None:
            return d
        lk, data = self.lmv.getattr_lock(parent, name, want_ea=True)
        idx = data.get("_granted_by")
        gmdc = self.lmv.mdcs[idx] if idx is not None else mdc
        if data.get("status", 0) == -2:
            d = Dentry(None, None, lk.handle if lk else None, gmdc)
        elif data.get("status", 0) != 0:
            raise FsError(data["status"], name)
        else:
            d = Dentry(tuple(data["attrs"]["fid"]), dict(data["attrs"]),
                       lk.handle if lk else None, gmdc)
            if "ea" in data:
                d.attrs["_ea"] = data["ea"]
            # the looked-up attrs ride under the same dir lock as the
            # dentry — cache them (the 2nd-hop remote path is flagged
            # `_remote`: its attrs have no covering lock here)
            if lk is not None and not data.get("_remote"):
                self._attr_put(d.fid, data["attrs"], data.get("ea"),
                               gmdc, lk.handle)
        self.dcache[key] = d
        return d

    # --------------------------------------------- negative-entry window
    def _neg_install(self, dfid, locks, names):
        """A COMPLETE readdir-plus listing bounds the directory's
        namespace: while every page's PR lock survives, any name NOT in
        the listing is known absent — a later lookup miss answers ENOENT
        with zero RPCs (§6.2.1 negative caching over the whole dir).
        Revoked with the dir lock, exactly like the positive entries."""
        if locks:
            self._neg_win[tuple(dfid)] = (locks, names)

    def _neg_lookup(self, key) -> Dentry | None:
        win = self._neg_win.get(key[0])
        if win is None:
            return None
        locks, names = win
        if any(h not in m.locks.locks for m, h in locks):
            del self._neg_win[key[0]]      # a page lock died: window void
            return None
        if key[1] in names:
            return None                    # listed name: not our answer
        self.sim.stats.count("fs.neg_hit")
        m, h = locks[0]
        d = Dentry(None, None, h, m)
        self.dcache[key] = d
        return d

    # -------------------------------------------------- fid attr cache
    def _attr_put(self, fid, attrs, ea, mdc, lock_handle):
        """Cache `fid`'s attrs under a covering dir lock. No lock, no
        cache — validity IS the lock (§7.4 applied to metadata)."""
        if lock_handle is None or lock_handle not in mdc.locks.locks:
            return
        fid = tuple(fid)
        self._attr_drop(fid)
        self.attr_cache[fid] = CachedAttr(dict(attrs), dict(ea or {}),
                                          mdc, lock_handle)
        self._attr_by_lock[(id(mdc), lock_handle)].add(fid)

    def _attr_drop(self, fid):
        e = self.attr_cache.pop(tuple(fid), None)
        if e is not None:
            s = self._attr_by_lock.get((id(e.mdc), e.lock_handle))
            if s:
                s.discard(tuple(fid))
        # one-shot statahead results for this fid die with it
        self._sa_attrs.pop(tuple(fid), None)
        self._sa_glimpse.pop(tuple(fid), None)

    def _attr_get(self, fid) -> CachedAttr | None:
        e = self.attr_cache.get(tuple(fid))
        if e is None:
            return None
        if e.lock_handle not in e.mdc.locks.locks:
            self._attr_drop(fid)               # lock gone: attrs invalid
            return None
        return e

    def _attrs_revoked(self, mdc, lk):
        """A dir lock left the MDC lock cache (blocking AST / cancel /
        eviction): every attr it covered is unprotected — drop them."""
        for fid in self._attr_by_lock.pop((id(mdc), lk.handle), ()):
            dropped = self.attr_cache.pop(fid, None)
            if dropped is not None:
                self.sim.stats.count("fs.attr_invalidate")

    def resolve(self, path: str, *, follow: bool = True,
                _depth: int = 0) -> tuple:
        if _depth > 8:
            raise FsError(-40, "ELOOP")
        fid = ROOT
        parts = self._parts(path)
        for i, name in enumerate(parts):
            last = i == len(parts) - 1
            if self.wbc is not None and self.wbc.active:
                handled, sfid = self.wbc.child(fid, name)
                if handled and sfid is None:
                    raise FsError(-2, path)     # authoritative ENOENT
                if handled:
                    sa = self.wbc.attrs(sfid)
                    if sa is not None:
                        # shadow-born inode: attrs (symlink target
                        # included) live entirely in the cache
                        if sa.get("type") == "symlink" and (
                                follow or not last):
                            rest = "/".join(parts[i + 1:])
                            target = sa.get("symlink", "")
                            return self.resolve(
                                target + "/" + rest if rest else target,
                                follow=follow, _depth=_depth + 1)
                        fid = sfid
                        continue
                    # pre-existing inode: fall through for real attrs
                    # (symlink detection needs them)
            d = self._lookup(fid, name)
            if d.fid is None:
                raise FsError(-2, path)
            if d.attrs and d.attrs.get("type") == "symlink" and (
                    follow or not last):
                data = self.lmv.getattr(d.fid)
                target = data.get("symlink", "")
                rest = "/".join(parts[i + 1:])
                return self.resolve(target + "/" + rest if rest else target,
                                    follow=follow, _depth=_depth + 1)
            fid = d.fid
        return tuple(fid)

    def _resolve_parent(self, path: str) -> tuple[tuple, str]:
        parts = self._parts(path)
        if not parts:
            raise FsError(-22, path)
        parent = self.resolve("/".join(parts[:-1])) if parts[:-1] else ROOT
        return parent, parts[-1]

    def _invalidate(self, parent: tuple, name: str):
        """Drop our own cached view of an entry we just mutated: the MDS
        spares OUR dir lock from the revocation storm (we are the
        requester), so fixing our caches is our job — the entry's
        dentry + attrs, and the parent dir's own attrs (its
        nlink/nentries changed)."""
        d = self.dcache.pop((tuple(parent), name), None)
        if d is not None and d.fid is not None:
            self._attr_drop(d.fid)
        self._attr_drop(tuple(parent))
        win = self._neg_win.get(tuple(parent))
        if win is not None:
            # we just mutated this entry ourselves: the window can no
            # longer prove the name absent (a create adds it)
            win[1].add(name)

    def _on_mds_evicted(self, mdc):
        """The MDS evicted us: the PR locks guarding cached dentries are
        gone server-side — drop them locally and purge the dcache (the
        drop_all fires revoke_cbs, which purge the attr cache entries
        those locks covered) plus the one-shot statahead results."""
        self.sim.stats.count("fs.evicted_invalidate")
        mdc.locks.drop_all()
        self.dcache.clear()
        self._neg_win.clear()
        self._sa.clear()
        self._sa_attrs.clear()
        self._sa_glimpse.clear()

    # --------------------------------------------------- wbc write routing
    def _make_wbc(self, fid) -> mdc_mod.WbcCache:
        w = mdc_mod.WbcCache(self.lmv, fid, batch=self.wbc_batch,
                             max_dirty=self.wbc_max_dirty,
                             max_rpcs=self.wbc_max_rpcs)
        w.destroy_cb = self._destroy_from_data
        return w

    def _wbc_covering(self, fid) -> mdc_mod.WbcCache | None:
        """The active WBC, if `fid` sits inside its subtree."""
        w = self.wbc
        if w is not None and w.active and w.in_subtree(fid):
            return w
        return None

    def _wbc_for_write(self, parent) -> mdc_mod.WbcCache | None:
        """The WBC a metadata write under `parent` should route through:
        the active cache when it covers the parent; else, with
        `wbc_auto`, an automatic entry attempt — the first metadata
        write under a directory asks the MDS for the subtree lock and
        the §6.5.2 contention decision grants or denies it. A denial is
        remembered (no re-ask storm). Never auto-grabs the fs root."""
        p = tuple(parent)
        w = self._wbc_covering(p)
        if w is None and self.wbc_auto \
                and (self.wbc is None or not self.wbc.active) \
                and p != tuple(ROOT) and p not in self._wbc_denied:
            w = self._make_wbc(p)
            if w.acquire():
                self.wbc = w
            else:
                self._wbc_denied.add(p)
                w = None
        if w is not None and self.lmv.mdc_for_fid(p) is not w.mdc:
            # cross-MDT record: the batch reintegrates only at the
            # subtree root's MDS — not representable, go synchronous
            return None
        return w

    def _wbc_sync_guard(self, *fids):
        """A synchronous metadata write is about to touch the WBC
        subtree (an op the shadow cannot represent: rename, hard link,
        cross-MDT entries, dirs split into buckets). Flush pending
        records first — server-side order must match local order — and
        make the shadow re-learn the touched directories."""
        w = self.wbc
        if w is None or not w.active:
            return
        touched = [tuple(f) for f in fids if w.in_subtree(f)]
        if not touched:
            return
        self.sim.stats.count("wbc.fallback_sync")
        w.flush()
        for f in touched:
            w.forget(f)

    # ------------------------------------------------------------- files
    def creat(self, path: str, *, stripe_count: int = 0,
              stripe_size: int = 0, stripe_offset: int = -1,
              mode: int = 0o644, pattern: str = "raid0") -> FileHandle:
        """lstripe-style create with explicit striping (ch. 32.1).
        pattern "raid5" adds a rotating parity stripe (ch. 15)."""
        return self.open(path, "cwx", stripe_count=stripe_count,
                         stripe_size=stripe_size,
                         stripe_offset=stripe_offset, mode=mode,
                         pattern=pattern)

    def open(self, path: str, flags: str = "r", *, stripe_count: int = 0,
             stripe_size: int = 0, stripe_offset: int = -1,
             mode: int = 0o644, pattern: str = "raid0") -> FileHandle:
        """flags: r read, w write, c create, x exclusive."""
        parent, name = self._resolve_parent(path)
        w = self._wbc_for_write(parent) if "c" in flags \
            else self._wbc_covering(parent)
        if w is not None:
            fh = self._wbc_open(w, parent, name, flags, stripe_count,
                                stripe_size, stripe_offset, mode, path,
                                pattern)
            if fh is not None:
                return fh
        if "c" in flags:
            # the create may mutate the subtree behind the shadow's back
            self._wbc_sync_guard(parent)
        lk, data = self.lmv.open(parent, name, flags, mode)
        st = data.get("status", 0)
        if st:
            raise FsError(st, path)
        self._invalidate(parent, name)
        attrs = data["attrs"]
        fid = tuple(attrs["fid"])
        self._attr_drop(fid)       # open-for-write flips mtime_on_ost
        ea = data.get("ea", {})
        if data.get("created"):
            # client creates the data objects + writes the EA (§6.4.3)
            lsm = self.lov.create(
                stripe_count=stripe_count or self.default_stripe_count,
                stripe_size=stripe_size or self.default_stripe_size,
                stripe_offset=stripe_offset, pattern=pattern)
            self.lmv.mdc_for_fid(fid).reint(
                {"type": "setattr", "fid": fid, "ea": {"lov": lsm.to_ea()}})
        elif "lov" in ea:
            lsm = lov_mod.StripeMd.from_ea(ea["lov"])
        else:
            lsm = None
        fh = FileHandle(fid, lsm, data.get("open_handle", 0), flags)
        self.handles[id(fh)] = fh
        return fh

    def _wbc_open(self, w, parent, name, flags, stripe_count, stripe_size,
                  stripe_offset, mode, path,
                  pattern: str = "raid0") -> FileHandle | None:
        """Open/create under the WBC: shadow-born files open with zero
        RPCs, and a create lands in the cache — the client still creates
        the stripe objects itself (§6.4.3), the LOV EA rides the
        create's follow-up setattr record. Returns None to take the
        synchronous path (pre-existing inode, or a directory listing the
        shadow cannot own)."""
        handled, fid = w.child(parent, name)
        if not handled:
            return None
        if fid is not None:
            if "c" in flags and "x" in flags:
                raise FsError(-17, path)
            sa = w.attrs(fid)
            if sa is None:
                return None                # pre-existing inode: sync open
            if sa.get("type") == "dir":
                raise FsError(-21, path)
            ea = sa.get("ea") or {}
            lsm = lov_mod.StripeMd.from_ea(ea["lov"]) \
                if "lov" in ea else None
            self.sim.stats.count("wbc.open_local")
            fh = FileHandle(fid, lsm, 0, flags, wbc=True)
            self.handles[id(fh)] = fh
            return fh
        if "c" not in flags:
            raise FsError(-2, path)        # authoritative ENOENT
        fid = w.create(parent, name, "file", mode)
        lsm = self.lov.create(
            stripe_count=stripe_count or self.default_stripe_count,
            stripe_size=stripe_size or self.default_stripe_size,
            stripe_offset=stripe_offset, pattern=pattern)
        w.setattr(fid, ea={"lov": lsm.to_ea()})
        self._invalidate(parent, name)
        fh = FileHandle(fid, lsm, 0, flags, wbc=True)
        self.handles[id(fh)] = fh
        return fh

    def write(self, fh: FileHandle, data: bytes, offset: int | None = None,
              gid: int = 0) -> int:
        if fh.lsm is None:
            raise FsError(-22, "no stripe md")
        off = fh.pos if offset is None else offset
        n = self.lov.write(fh.lsm, off, data, gid=gid)
        fh.pos = off + n
        fh.max_written = max(fh.max_written, off + n)
        fh.mtime = self.sim.now
        self.sim.stats.add_bytes("fs.write", n)
        return n

    def read(self, fh: FileHandle, length: int,
             offset: int | None = None) -> bytes:
        if fh.lsm is None:
            raise FsError(-22, "no stripe md")
        off = fh.pos if offset is None else offset
        # PR-locked size query: flushes any writer's write-back cache
        # before we trust the OST sizes (§6.2.3 ordering); served from
        # the cached locks' value blocks when warm (zero RPCs)
        size = self.lov.getattr_locked(fh.lsm)["size"]
        length = max(0, min(length, size - off))
        if length == 0:
            return b""
        out = self.lov.read(fh.lsm, off, length)
        self._maybe_readahead(fh, off, len(out), size)
        fh.pos = off + len(out)
        self.sim.stats.add_bytes("fs.read", len(out))
        return out

    def _maybe_readahead(self, fh: FileHandle, off: int, nread: int,
                         size: int):
        """Per-handle sequential-read detector: a read starting exactly
        where the last one ended (or at 0 on a fresh handle) extends a
        readahead window that ramps up to `readahead_pages`, fetched
        stripe-aware into the OSC clean caches (one vectored OST_READ per
        stripe object). A seek resets the window."""
        ra_max = self.readahead_pages * osc_mod.PAGE_SIZE
        if ra_max <= 0:
            return
        if off != fh.ra_next:
            # seek: not sequential — back off, and forget the old fetch
            # horizon (a stale ra_pos ahead of a backward seek would
            # suppress refills for the whole re-scanned range; refetching
            # still-cached runs costs zero RPCs, readv skips them)
            fh.ra_window = 0
            fh.ra_pos = off + nread
            fh.ra_next = off + nread
            return
        fh.ra_next = off + nread
        fh.ra_window = min(ra_max, max(fh.ra_window * 2, ra_max // 4, 1))
        # hysteresis: refill only when less than half a window is still
        # ahead of the reader, and then fetch a FULL window — large
        # batched fetches, not a per-read top-up RPC
        ahead = fh.ra_pos - (off + nread)
        if ahead >= fh.ra_window // 2:
            return
        start = max(off + nread, fh.ra_pos)
        end = min(off + nread + fh.ra_window, size)
        if end > start:
            self.lov.readahead(fh.lsm, start, end - start)
            fh.ra_pos = end
            self.sim.stats.count("fs.readahead")
            self.sim.stats.add_bytes("fs.readahead", end - start)

    def _fsync_data(self, fh: FileHandle):
        if fh.lsm is not None:
            self.sim.parallel([
                (lambda u=u: self.lov.by_uuid[u].flush())
                for u in {o["ost"] for o in fh.lsm.objects}])

    def fsync(self, fh: FileHandle):
        """Flush the handle's dirty data — and, under WBC, reintegrate
        pending metadata too: fsync is a durability barrier, so the
        file's create/setattr records must reach the MDS (§17.2)."""
        self._fsync_data(fh)
        w = self._wbc_covering(fh.fid)
        if w is not None and w.records:
            self.sim.stats.count("wbc.fsync_barrier")
            w.flush()

    def close(self, fh: FileHandle):
        """Flush + ship size/mtime to the MDS (§6.9.1: the OSTs owned them
        while the file was open for write). A WBC handle's size/mtime
        land as a setattr record instead — close is not a reintegration
        point (ch. 17), fsync and release are."""
        self._fsync_data(fh)
        size = mtime = None
        if "w" in fh.flags or "c" in fh.flags:
            if fh.lsm is not None:
                a = self.lov.getattr(fh.lsm)
                size, mtime = a["size"], max(a["mtime"], fh.mtime)
        if fh.wbc:
            w = self._wbc_covering(fh.fid)
            if w is not None and w.attrs(fh.fid) is not None:
                if size is not None:
                    w.setattr(fh.fid, attrs={"size": size, "mtime": mtime})
            elif size is not None:
                # the cache died since the open: reintegrate size/mtime
                # synchronously (the create either flushed — fid exists —
                # or was lost with the lock: nothing left to update)
                try:
                    self.lmv.mdc_for_fid(fh.fid).reint(
                        {"type": "setattr", "fid": fh.fid,
                         "attrs": {"size": size, "mtime": mtime}})
                except R.RpcError:
                    self.sim.stats.count("wbc.orphan_close")
            self._attr_drop(fh.fid)
            self.handles.pop(id(fh), None)
            return
        self.lmv.close(fh.fid, fh.open_handle, size, mtime)
        self._attr_drop(fh.fid)    # size/mtime just moved to the MDS
        self.handles.pop(id(fh), None)

    # ------------------------------------------------------------- dirs
    def mkdir(self, path: str, mode: int = 0o755) -> tuple:
        parent, name = self._resolve_parent(path)
        w = self._wbc_for_write(parent)
        if w is not None:
            handled, fid = w.child(parent, name)
            if handled:
                if fid is not None:
                    raise FsError(-17, path)
                self._invalidate(parent, name)
                return w.create(parent, name, "dir", mode)
        self._wbc_sync_guard(parent)
        rep = self.lmv.reint({"type": "create", "parent": parent,
                              "name": name, "ftype": "dir", "mode": mode})
        self._invalidate(parent, name)
        return tuple(rep.data["fid"])

    def mkdir_p(self, path: str) -> tuple:
        fid = ROOT
        parts = self._parts(path)
        for i in range(len(parts)):
            sub = "/" + "/".join(parts[:i + 1])
            try:
                fid = self.resolve(sub)
            except FsError:
                fid = self.mkdir(sub)
        return tuple(fid)

    def readdir(self, path: str) -> dict:
        fid = self.resolve(path)
        w = self._wbc_covering(fid)
        if w is not None:
            listing = w.listing(fid)
            if listing is not None:
                # the shadow owns this listing: zero RPCs once seeded
                self.sim.stats.count("wbc.readdir_local")
                return {k: tuple(v) for k, v in listing.items()}
        out = {k: tuple(v)
               for k, v in self.lmv.readdir(fid)["entries"].items()}
        # the listing order seeds the statahead detector: stats walking
        # it sequentially will prefetch attr windows (ISSUE-5)
        self._sa_record(fid, out.items())
        return out

    def _sa_record(self, dfid, order):
        """Install a directory's statahead detector, keeping only the
        most recently listed directories (a whole-namespace walk must
        not pin a (name, fid) listing per directory forever)."""
        self._sa.pop(tuple(dfid), None)
        self._sa[tuple(dfid)] = _Statahead(order)
        while len(self._sa) > 64:
            self._sa.pop(next(iter(self._sa)))

    def _absorb_page(self, dfid, mdc, lk, page):
        """Feed one readdir-plus page into the dentry + attr caches:
        every entry is covered by the page's dir/bucket PR lock. Attrs
        of entries whose inode a peer MDT owns (flagged `remote`) have
        no covering lock — they serve this pass only."""
        if lk is None:
            return
        dfid = tuple(dfid)
        for name, e in page.items():
            attrs = e.get("attrs")
            if attrs is None:
                continue
            fid = tuple(e["fid"])
            self.dcache[(dfid, name)] = Dentry(fid, dict(attrs),
                                               lk.handle, mdc)
            if not e.get("remote"):
                self._attr_put(fid, attrs, e.get("ea"), mdc, lk.handle)

    def _iter_plus(self, dfid):
        """readdir-plus iteration of ONE directory: yields (name, fid,
        attrs, ea) while absorbing pages into the caches and recording
        the statahead order."""
        order = []
        locks: list = []
        names: set = set()
        complete = True
        for mdc, lk, page in self.lmv.readdir_plus(dfid, self.dir_pages):
            self._absorb_page(dfid, mdc, lk, page)
            if lk is None:
                complete = False           # unlocked page: no window
            elif (mdc, lk.handle) not in locks:
                locks.append((mdc, lk.handle))
            for name, e in page.items():
                names.add(name)
                fid = tuple(e["fid"])
                attrs, ea = e.get("attrs"), e.get("ea") or {}
                if attrs is None:
                    # raced removal of a remote inode: sync fallback
                    try:
                        d = self.lmv.getattr(fid, want_ea=True)
                    except R.RpcError:
                        continue
                    attrs, ea = d["attrs"], d.get("ea", {})
                order.append((name, fid))
                yield name, fid, dict(attrs), ea
        self._sa_record(dfid, order)
        if complete:
            self._neg_install(tuple(dfid), locks, names)

    def ls_l(self, path: str) -> dict:
        """`ls -l`: name -> full stat attrs for every entry. With
        `dir_pages` set the listing is readdir-plus paged — attrs + LOV
        EAs ride the directory pages under the dir's PR lock, and the
        sizes of files under write are resolved with ONE batched glimpse
        per OST across ALL of them. dir_pages=0 keeps the seed shape
        (readdir + per-entry stat), still statahead-accelerated when
        statahead_max > 0."""
        wbc_owned = False
        if self.wbc is not None and self.wbc.active:
            try:
                f = self.resolve(path)
            except FsError:
                f = None
            wbc_owned = f is not None and self._wbc_covering(f) is not None \
                and self.wbc.listing(f) is not None
        if not self.dir_pages or wbc_owned:
            # shadow-owned dirs: a server-side readdir-plus would miss
            # the unflushed entries — list + stat through the shadow
            base = "/" + "/".join(self._parts(path))
            base = "" if base == "/" else base
            return {name: self.stat(f"{base}/{name}")
                    for name in self.readdir(path)}
        fid = self.resolve(path)
        out: dict[str, dict] = {}
        glimpse_lsm: dict[tuple, lov_mod.StripeMd] = {}
        glimpse_names: dict[tuple, list] = {}   # fid -> EVERY linked name
        for name, f, a, ea in self._iter_plus(fid):
            if a.get("mtime_on_ost") and "lov" in ea:
                glimpse_lsm[f] = lov_mod.StripeMd.from_ea(ea["lov"])
                glimpse_names.setdefault(f, []).append(name)
            if "lov" in ea:
                a["stripe_count"] = ea["lov"]["stripe_count"]
                a["stripe_size"] = ea["lov"]["stripe_size"]
            out[name] = a
        if glimpse_lsm:
            # size/mtime of files under write live on the OSTs (§6.9.1):
            # one vectored glimpse per OST covers every such file
            res = self.lov.glimpse_files(glimpse_lsm)
            for f, names in glimpse_names.items():
                g = res[f]
                for name in names:     # hard links share the one answer
                    out[name] = dict(out[name], size=g["size"],
                                     mtime=max(out[name]["mtime"],
                                               g["mtime"]))
        return out

    def walk(self):
        """Iterative whole-namespace walk (split-directory buckets
        included via the LMV): yields (parent_fid, name, fid, attrs) for
        every directory entry — the 'initial scan' primitive
        Robinhood-style consumers bootstrap from
        (tools.audit.ChangelogAuditor(bootstrap=True)). With `dir_pages`
        set it rides readdir-plus: attrs arrive WITH the directory pages
        (O(N/page) RPCs + one getattr_bulk per MDT per page for
        cross-MDT inodes), instead of one getattr per entry."""
        stack = [ROOT]
        seen = {ROOT}
        while stack:
            dfid = stack.pop()
            if self.dir_pages:
                for name, fid, attrs, _ in self._iter_plus(dfid):
                    yield tuple(dfid), name, fid, attrs
                    if attrs["type"] == "dir" and fid not in seen:
                        seen.add(fid)
                        stack.append(fid)
                continue
            for name, fid in self.lmv.readdir(dfid)["entries"].items():
                fid = tuple(fid)
                attrs = self.lmv.getattr(fid)["attrs"]
                yield tuple(dfid), name, fid, attrs
                if attrs["type"] == "dir" and fid not in seen:
                    seen.add(fid)
                    stack.append(fid)

    def symlink(self, target: str, path: str):
        parent, name = self._resolve_parent(path)
        w = self._wbc_for_write(parent)
        if w is not None:
            handled, fid = w.child(parent, name)
            if handled:
                if fid is not None:
                    raise FsError(-17, path)
                w.create(parent, name, "symlink", 0o777, target=target)
                self._invalidate(parent, name)
                return
        self._wbc_sync_guard(parent)
        self.lmv.reint({"type": "create", "parent": parent, "name": name,
                        "ftype": "symlink", "target": target})
        self._invalidate(parent, name)

    def link(self, existing: str, path: str):
        fid = self.resolve(existing)
        parent, name = self._resolve_parent(path)
        # hard links (possibly reaching out of the subtree) are not
        # representable in the shadow: flush + synchronous (ch. 17)
        self._wbc_sync_guard(fid, parent)
        self.lmv.reint({"type": "link", "parent": parent, "name": name,
                        "fid": fid})
        self._invalidate(parent, name)
        self._attr_drop(fid)       # its nlink just changed

    def rename(self, old: str, new: str):
        sp, sn = self._resolve_parent(old)
        dp, dn = self._resolve_parent(new)
        # renames can cross the subtree boundary or MDTs — not
        # representable in the shadow: flush + synchronous (ch. 17)
        self._wbc_sync_guard(sp, dp)
        rep = self.lmv.reint({"type": "rename", "src": sp, "src_name": sn,
                              "dst": dp, "dst_name": dn})
        self._invalidate(sp, sn)
        self._invalidate(dp, dn)
        # rename-over displaced the old target's last link: destroy its
        # data objects exactly as unlink does
        self._destroy_from_reply(rep)

    def unlink(self, path: str):
        parent, name = self._resolve_parent(path)
        w = self._wbc_for_write(parent)
        if w is not None:
            handled, fid = w.child(parent, name)
            if handled and fid is None:
                raise FsError(-2, path)     # authoritative ENOENT
            if handled and (sa := w.attrs(fid)) is not None:
                # shadow-born inode: the unlink is fully local
                if sa.get("type") == "dir" and w.listing(fid):
                    raise FsError(-39, path)
                w.unlink(parent, name)
                self._invalidate(parent, name)
                return
        # pre-existing inode (the MDS owns its nlink/emptiness checks
        # and hands back the EA for object destroys): synchronous
        self._wbc_sync_guard(parent)
        rep = self.lmv.reint({"type": "unlink", "parent": parent,
                              "name": name})
        self._invalidate(parent, name)
        self._destroy_from_reply(rep)

    rmdir = unlink

    def _destroy_from_reply(self, rep):
        self._destroy_from_data(rep.data or {})

    def _destroy_from_data(self, data: dict):
        """Last link gone (unlink or rename-over, synchronous or via a
        flushed WBC record): the LOV EA + llog cookies hand the object
        destroys to THE CLIENT; OSTs cancel the MDS records once their
        destroys commit (ch. 8.4)."""
        ea = data.get("ea") or {}
        if "lov" in ea:
            lsm = lov_mod.StripeMd.from_ea(ea["lov"])
            self.lov.destroy(lsm, data.get("cookies"))

    # -------------------------------------------------------- statahead
    def _sa_note_stat(self, dfid, name: str):
        """Statahead detector: a stat hitting the next entry of the last
        readdir order extends a sequential run; at run >= 2 the next
        window of entries' attrs is prefetched in batch (the metadata
        analogue of the PR 4 sequential-read detector)."""
        st = self._sa.get(tuple(dfid))
        if st is None or self.statahead_max <= 0:
            return
        i = st.index.get(name)
        if i is None:
            return
        st.run = st.run + 1 if i == st.pos + 1 else 1
        st.pos = i
        if st.run >= 2 and i + 1 < len(st.order) \
                and st.fetched < i + 1 + self.statahead_max // 2:
            self._sa_prefetch(tuple(dfid), st, i + 1)

    def _sa_prefetch(self, dfid, st: _Statahead, lo: int):
        """Prefetch the next statahead window: ONE getattr_bulk per
        owning MDT (issued concurrently), then ONE vectored glimpse per
        OST for the fetched files whose size/mtime live on the OSTs.
        Attrs of entries the directory's PR lock covers land in the
        coherent attr cache; the rest (cross-MDT inodes, glimpses) are
        one-shot. An armed `mds.statahead` failpoint (drop/crash —
        client-side, crash degrades to drop) abandons the prefetch: the
        following stats simply stay synchronous."""
        hi = min(len(st.order), lo + self.statahead_max)
        lo = max(lo, st.fetched)
        window = [(n, tuple(f)) for n, f in st.order[lo:hi]
                  if self._attr_get(f) is None
                  and tuple(f) not in self._sa_attrs]
        if not window:
            st.fetched = max(st.fetched, hi)
            return
        act = fail_mod.state.check("mds.statahead")
        if act in ("drop", "crash"):
            self.sim.stats.count("fs.statahead_dropped")
            return
        dmdc = self.lmv.mdc_for_fid(dfid)
        lk = dmdc.locks.match(("fid", *tuple(dfid)), "PR")
        if lk is None:
            # one PR enqueue on the dir covers the whole pipeline: the
            # MDS revokes it on any namespace or child-attr change
            lk, _, _ = dmdc.locks.enqueue(("fid", *tuple(dfid)), "PR")
        by_mdc: dict = {}
        for n, f in window:
            by_mdc.setdefault(self.lmv.mdc_for_fid(f), []).append((n, f))

        def fetch(m, items):
            return m, items, m.getattr_bulk([f for _, f in items],
                                            want_ea=True)

        outs = self.sim.parallel([(lambda m=m, it=it: fetch(m, it))
                                  for m, it in by_mdc.items()])
        glimpse: dict = {}
        for m, items, attrs in outs:
            for (n, f), a in zip(items, attrs):
                if a is None:
                    continue
                if m is dmdc and lk is not None:
                    self._attr_put(f, a["attrs"], a.get("ea"),
                                   dmdc, lk.handle)
                    self.dcache[(tuple(dfid), n)] = Dentry(
                        f, dict(a["attrs"]), lk.handle, dmdc)
                else:
                    # no covering lock on the OWNING MDT — serve once,
                    # valid only while the dir lock the prefetch ran
                    # under survives (a remote setattr forwards its
                    # revocation to that lock via the inode's
                    # remote_pfids, killing this entry with it)
                    self._sa_attrs[f] = (dmdc, lk.handle if lk else None,
                                         a)
                ea = a.get("ea") or {}
                if a["attrs"].get("mtime_on_ost") and "lov" in ea:
                    glimpse[f] = lov_mod.StripeMd.from_ea(ea["lov"])
        if glimpse:
            for f, g in self.lov.glimpse_files(glimpse).items():
                self._sa_glimpse[f] = (dmdc, lk.handle if lk else None, g)
        st.fetched = max(st.fetched, hi)
        # one-shot results are disposable (an unconsumed entry just
        # costs a sync re-fetch): bound both pools
        if len(self._sa_attrs) > 4096:
            self._sa_attrs.clear()
        if len(self._sa_glimpse) > 4096:
            self._sa_glimpse.clear()
        self.sim.stats.count("fs.statahead")
        self.sim.stats.count("fs.statahead_entries", len(window))

    def _sa_pop(self, pool: dict, fid):
        """Consume a one-shot statahead result iff the dir lock it was
        prefetched under is STILL held — a revocation (including one
        forwarded cross-MDT) since the prefetch voids it."""
        e = pool.pop(tuple(fid), None)
        if e is None:
            return None
        mdc, handle, payload = e
        if handle is None or handle not in mdc.locks.locks:
            self.sim.stats.count("fs.statahead_stale_dropped")
            return None
        return payload

    # ------------------------------------------------------------- stat
    def stat(self, path: str) -> dict:
        parts = self._parts(path)
        fid = self.resolve(path)
        if self.wbc is not None and self.wbc.active:
            sa = self.wbc.attrs(fid)
            if sa is not None:
                # shadow-born inode: attrs live in the cache, zero RPCs
                self.sim.stats.count("wbc.stat_local")
                a = dict(sa)
                ea = dict(a.pop("ea", None) or {})
                if "lov" in ea:
                    a["stripe_count"] = ea["lov"]["stripe_count"]
                    a["stripe_size"] = ea["lov"]["stripe_size"]
                return a
        if parts:
            # statahead bookkeeping keyed by the parent as spelled in
            # the path (a symlinked parent just misses the detector)
            try:
                parent = self.resolve("/".join(parts[:-1])) \
                    if parts[:-1] else ROOT
                self._sa_note_stat(parent, parts[-1])
            except FsError:
                pass
        ca = self._attr_get(fid)
        if ca is not None:
            # warm path: the covering dir lock is still held — zero RPCs
            self.sim.stats.count("fs.attr_hit")
            a, ea = dict(ca.attrs), dict(ca.ea)
        else:
            one = self._sa_pop(self._sa_attrs, fid)
            if one is not None:
                self.sim.stats.count("fs.statahead_hit")
                a, ea = dict(one["attrs"]), dict(one.get("ea") or {})
            else:
                self.sim.stats.count("fs.attr_miss")
                d = self.lmv.getattr(fid, want_ea=True)
                a, ea = dict(d["attrs"]), dict(d.get("ea") or {})
        if a.get("mtime_on_ost") and "lov" in ea:
            # size/mtime live on the OSTs while a writer is active
            # (§6.9.1): a statahead-prefetched glimpse answers for free,
            # else one batched glimpse per OST (writers keep their locks)
            g = self._sa_pop(self._sa_glimpse, fid)
            if g is None:
                g = self.lov.glimpse(lov_mod.StripeMd.from_ea(ea["lov"]))
            a = dict(a, size=g["size"], mtime=max(a["mtime"], g["mtime"]))
        if "lov" in ea:
            a["stripe_count"] = ea["lov"]["stripe_count"]
            a["stripe_size"] = ea["lov"]["stripe_size"]
        return a

    def setattr(self, path: str, *, mode=None, uid=None, gid=None,
                mtime=None, size=None) -> dict:
        """mds_reint_setattr on the path's inode (chmod/chown/utimes/
        metadata truncate). The MDS revokes every directory PR lock
        covering cached copies of these attrs — ours included — so no
        client ever serves them stale."""
        fid = self.resolve(path)
        attrs = {k: v for k, v in (("mode", mode), ("uid", uid),
                                   ("gid", gid), ("mtime", mtime),
                                   ("size", size)) if v is not None}
        w = self.wbc
        if w is not None and w.active and w.attrs(fid) is not None:
            # shadow-born inode: the setattr is one more cache record
            w.setattr(fid, attrs=attrs)
            self._attr_drop(fid)
            return dict(w.attrs(fid))
        rep = self.lmv.reint({"type": "setattr", "fid": fid,
                              "attrs": attrs})
        self._attr_drop(fid)       # we changed them: our copy is stale
        return rep.data["attrs"]

    def chmod(self, path: str, mode: int) -> dict:
        return self.setattr(path, mode=mode)

    def truncate(self, path: str, size: int):
        """Truncate: punch the stripe objects, then setattr the MDS size
        (which revokes the attr-covering dir locks)."""
        fid = self.resolve(path)
        ca = self._attr_get(fid)
        if ca is not None:
            ea = dict(ca.ea)
        elif self.wbc is not None and self.wbc.active \
                and self.wbc.attrs(fid) is not None:
            ea = dict(self.wbc.attrs(fid).get("ea") or {})
        else:
            ea = self.lmv.getattr(fid, want_ea=True).get("ea", {})
        if "lov" in ea:
            self.lov.punch(lov_mod.StripeMd.from_ea(ea["lov"]), size)
        self.setattr(path, size=size, mtime=self.sim.now)

    def exists(self, path: str) -> bool:
        try:
            self.resolve(path)
            return True
        except FsError:
            return False

    # ------------------------------------------------------ raid5 rebuild
    def deactivate_ost(self, uuid: str):
        """`lctl --device deactivate`: mark an OST dead for this client —
        raid5 paths go degraded immediately instead of timing out."""
        self.lov.set_active(uuid, False)

    def activate_ost(self, uuid: str):
        self.lov.set_active(uuid, True)

    def rebuild_ost(self, dead_uuid: str, spare_uuid: str, *,
                    jobid: str = "rebuild",
                    limit: int | None = None) -> dict:
        """Background rebuilder (ch. 15): walk the namespace, and for
        every raid5 file striped over `dead_uuid` reconstruct the dead
        slot's object onto `spare_uuid` from survivors + parity, then
        swap the file's layout to the rebuilt object.

        * All reconstruction I/O is tagged with `jobid` so a ``tbf_orr``
          NRS rule ({"rebuild": rate}) throttles it server-side without
          starving client traffic.
        * The layout swap is a reint setattr on the LOV EA — the MDS
          applies it under its inode lock and revokes every attr-covering
          DLM lock, so readers re-fetch the EA atomically and never see
          a torn layout; a reader mid-degraded-read keeps using the OLD
          layout, which stays valid (the dead slot still reconstructs).
        * OBD_FAIL sites: ``lov.rebuild`` fires before each file's
          reconstruction, ``lov.layout_swap`` before each EA commit —
          both abort the walk with the old layout intact (crash-sweep
          proves no torn layouts / stale data either way).
        * ``limit`` caps the number of files rebuilt in this call (the
          batch-paced rebuild knob — callers interleave batches with
          foreground work; every file left behind still serves degraded
          reads and a later call resumes where the layouts say).
        """
        report = {"rebuilt": 0, "swapped": 0, "skipped": 0, "bytes": 0,
                  "aborted": False}
        spare = self.lov.by_uuid[spare_uuid]
        prev_jobid = self.rpc.jobid
        prev_active = self.lov.is_active(dead_uuid)
        self.set_jobid(jobid)
        self.lov.set_active(dead_uuid, False)
        try:
            for _, _, fid, attrs in self.walk():
                if attrs.get("type") != "file":
                    continue
                ea = self.lmv.getattr(fid, want_ea=True).get("ea") or {}
                if "lov" not in ea:
                    continue
                lsm = lov_mod.StripeMd.from_ea(ea["lov"])
                if lsm.pattern != "raid5" or not any(
                        o["ost"] == dead_uuid for o in lsm.objects):
                    report["skipped"] += 1
                    continue
                if fail_mod.state.check("lov.rebuild") in ("drop", "crash"):
                    # client-side site: the rebuilder dies mid-walk — no
                    # layout touched yet, a rerun finishes the job
                    self.sim.stats.count("lov.rebuild_aborted")
                    report["aborted"] = True
                    return report
                before = self.sim.stats.counters.get("lov.rebuild_bytes", 0)
                new_lsm = self.lov.rebuild_object(lsm, dead_uuid, spare)
                if new_lsm is None:
                    report["skipped"] += 1
                    continue
                report["rebuilt"] += 1
                report["bytes"] += \
                    self.sim.stats.counters.get("lov.rebuild_bytes", 0) \
                    - before
                if fail_mod.state.check("lov.layout_swap") in ("drop",
                                                               "crash"):
                    # abort BEFORE the EA commit: the old layout stays
                    # intact (still degraded-readable); the spare object
                    # is merely orphaned
                    self.sim.stats.count("lov.rebuild_aborted")
                    report["aborted"] = True
                    return report
                self.lmv.mdc_for_fid(fid).reint(
                    {"type": "setattr", "fid": fid,
                     "ea": {"lov": new_lsm.to_ea()}})
                self._attr_drop(fid)
                self.sim.stats.count("lov.layout_swap")
                report["swapped"] += 1
                if limit is not None and report["rebuilt"] >= limit:
                    break
        finally:
            self.set_jobid(prev_jobid)
            self.lov.set_active(dead_uuid, prev_active)
        return report

    # -------------------------------------------------- jobid / changelog
    def set_jobid(self, jobid: str):
        """Tag every subsequent RPC from this client with a batch-job id
        (the JOBENV model): the same tag drives TBF NRS classification on
        servers and attribution in changelog records."""
        self.rpc.jobid = jobid

    def changelog_register(self, *, mdt: int = 0) -> str:
        return self.lmv.mdcs[mdt].changelog_register()

    def changelog_deregister(self, user: str, *, mdt: int = 0):
        self.lmv.mdcs[mdt].changelog_deregister(user)

    def changelog_read(self, user: str, *, mdt: int = 0,
                       since_idx: int | None = None,
                       count: int = 0) -> list[dict]:
        return self.lmv.mdcs[mdt].changelog_read(user, since_idx, count)

    def changelog_clear(self, user: str, up_to: int, *,
                        mdt: int = 0) -> dict:
        return self.lmv.mdcs[mdt].changelog_clear(user, up_to)

    def statfs(self) -> dict:
        mds = self.lmv.statfs()
        osts = [o.statfs() for o in self.lov.oscs]
        return {"mds": mds,
                "capacity": sum(o["capacity"] for o in osts),
                "free": sum(o["free"] for o in osts),
                "objects": sum(o["objects"] for o in osts)}

    # ----------------------------------------------------- wbc lifecycle
    def enable_wbc(self, path: str) -> bool:
        """Enter metadata write-back mode for a subtree (ch. 17)."""
        fid = self.resolve(path)
        wbc = self._make_wbc(fid)
        if wbc.acquire():
            self.wbc = wbc
            return True
        return False

    def disable_wbc(self):
        if self.wbc:
            self.wbc.release()
            self.wbc = None

    def sync(self):
        if self.wbc:
            self.wbc.flush()
        self.lov.flush()
