"""LustreClient: the client filesystem (paper ch. 9, 28 — "Lustre Lite").

POSIX-ish API over the LMV (metadata) + LOV (data) stacks:
  * path resolution with a *dentry cache* guarded by DLM locks — an entry is
    valid exactly while its PR lock is held; server-side updates revoke via
    blocking ASTs (ch. 28.4); negative entries are cached too (§6.2.1);
  * `open(path, "cw")` is ONE intent RPC doing lookup+create+open (§6.4.3);
    the client then creates the stripe objects and writes the LOV EA back
    (the MDS returned the new inode under a lock so only this client
    creates objects);
  * file I/O through LOV striping under extent locks, write-back cached
    with grants (ch. 10, 28.5);
  * size/mtime: while a file is open for write the OSTs own mtime/size;
    `close` ships them to the MDS (§6.9.1); `stat` consults the OSTs when
    the MDS flag says so;
  * optional metadata write-back-cache mode for create-heavy directories
    (ch. 17).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core import lov as lov_mod
from repro.core import mdc as mdc_mod
from repro.core import osc as osc_mod
from repro.core import mds as mds_mod
from repro.core import ptlrpc as R
from repro.core.cluster import LustreCluster

ROOT = mds_mod.ROOT_FID


class FsError(Exception):
    def __init__(self, errno: int, msg: str = ""):
        super().__init__(f"errno {errno}: {msg}")
        self.errno = errno


@dataclasses.dataclass
class FileHandle:
    fid: tuple
    lsm: Optional[lov_mod.StripeMd]
    open_handle: int
    flags: str
    pos: int = 0
    max_written: int = 0
    mtime: float = 0.0
    # per-handle sequential-read detector state (readahead):
    ra_next: int = 0           # offset the next sequential read starts at
    ra_window: int = 0         # current readahead window (bytes, ramps up)
    ra_pos: int = 0            # how far readahead has already fetched


@dataclasses.dataclass
class Dentry:
    fid: tuple | None            # None = negative entry
    attrs: dict | None
    lock_handle: int | None      # validity = lock still held


class LustreClient:
    def __init__(self, cluster: LustreCluster, node_idx: int = 0,
                 default_stripe_count: int = 0,
                 default_stripe_size: int = 1 << 20,
                 max_pages_per_rpc: int | None = None,
                 max_rpcs_in_flight: int | None = None,
                 vectored_brw: bool | None = None,
                 max_cached_mb: int | None = None,
                 readahead_pages: int | None = None):
        self.cluster = cluster
        self.rpc = cluster.make_client_rpc(node_idx)
        self.lmv = cluster.make_lmv(self.rpc)
        # BRW pipeline + read cache knobs: per-client override of the
        # cluster defaults
        osc_kw = {k: v for k, v in (
            ("max_pages_per_rpc", max_pages_per_rpc),
            ("max_rpcs_in_flight", max_rpcs_in_flight),
            ("vectored_brw", vectored_brw),
            ("max_cached_mb", max_cached_mb)) if v is not None}
        self.lov = cluster.make_lov(self.rpc, **osc_kw)
        self.readahead_pages = cluster.readahead_pages \
            if readahead_pages is None else readahead_pages
        self.sim = cluster.sim
        # eviction by an MDS voids every lock that guards the dentry
        # cache: drop the locks (local-only) and the dentries with them
        for mdc in self.lmv.mdcs:
            mdc.imp.evict_cbs.append(
                lambda m=mdc: self._on_mds_evicted(m))
        self.default_stripe_count = default_stripe_count or len(
            cluster.ost_targets)
        self.default_stripe_size = default_stripe_size
        self.dcache: dict[tuple, Dentry] = {}     # (parent, name) -> Dentry
        self._fh = itertools.count(1)
        self.handles: dict[int, FileHandle] = {}
        self.wbc: mdc_mod.WbcCache | None = None

    # ------------------------------------------------------------- mount
    def mount(self) -> "LustreClient":
        self.lmv.getattr(ROOT)
        return self

    # ------------------------------------------------------ path walking
    @staticmethod
    def _parts(path: str) -> list[str]:
        return [p for p in path.split("/") if p]

    def _dentry_valid(self, key, mdc) -> bool:
        d = self.dcache.get(key)
        if d is None:
            return False
        if d.lock_handle is None:
            return False
        return d.lock_handle in mdc.locks.locks

    def _lookup(self, parent: tuple, name: str) -> Dentry:
        key = (tuple(parent), name)
        mdc = self.lmv.mdc_for_fid(parent)
        if self._dentry_valid(key, mdc):
            self.sim.stats.count("fs.dcache_hit")
            return self.dcache[key]
        lk, data = self.lmv.getattr_lock(parent, name, want_ea=True)
        if data.get("status", 0) == -2:
            d = Dentry(None, None, lk.handle if lk else None)
        elif data.get("status", 0) != 0:
            raise FsError(data["status"], name)
        else:
            d = Dentry(tuple(data["attrs"]["fid"]), dict(data["attrs"]),
                       lk.handle if lk else None)
            if "ea" in data:
                d.attrs["_ea"] = data["ea"]
        self.dcache[key] = d
        return d

    def resolve(self, path: str, *, follow: bool = True,
                _depth: int = 0) -> tuple:
        if _depth > 8:
            raise FsError(-40, "ELOOP")
        fid = ROOT
        parts = self._parts(path)
        for i, name in enumerate(parts):
            if self.wbc and self.wbc.active:
                sfid = self.wbc.lookup(fid, name)
                if sfid is not None:
                    fid = sfid
                    continue
            d = self._lookup(fid, name)
            if d.fid is None:
                raise FsError(-2, path)
            last = i == len(parts) - 1
            if d.attrs and d.attrs.get("type") == "symlink" and (
                    follow or not last):
                data = self.lmv.getattr(d.fid)
                target = data.get("symlink", "")
                rest = "/".join(parts[i + 1:])
                return self.resolve(target + "/" + rest if rest else target,
                                    follow=follow, _depth=_depth + 1)
            fid = d.fid
        return tuple(fid)

    def _resolve_parent(self, path: str) -> tuple[tuple, str]:
        parts = self._parts(path)
        if not parts:
            raise FsError(-22, path)
        parent = self.resolve("/".join(parts[:-1])) if parts[:-1] else ROOT
        return parent, parts[-1]

    def _invalidate(self, parent: tuple, name: str):
        self.dcache.pop((tuple(parent), name), None)

    def _on_mds_evicted(self, mdc):
        """The MDS evicted us: the PR locks guarding cached dentries are
        gone server-side — drop them locally and purge the dcache."""
        self.sim.stats.count("fs.evicted_invalidate")
        mdc.locks.drop_all()
        self.dcache.clear()

    # ------------------------------------------------------------- files
    def creat(self, path: str, *, stripe_count: int = 0,
              stripe_size: int = 0, stripe_offset: int = -1,
              mode: int = 0o644) -> FileHandle:
        """lstripe-style create with explicit striping (ch. 32.1)."""
        return self.open(path, "cwx", stripe_count=stripe_count,
                         stripe_size=stripe_size,
                         stripe_offset=stripe_offset, mode=mode)

    def open(self, path: str, flags: str = "r", *, stripe_count: int = 0,
             stripe_size: int = 0, stripe_offset: int = -1,
             mode: int = 0o644) -> FileHandle:
        """flags: r read, w write, c create, x exclusive."""
        parent, name = self._resolve_parent(path)
        lk, data = self.lmv.open(parent, name, flags, mode)
        st = data.get("status", 0)
        if st:
            raise FsError(st, path)
        self._invalidate(parent, name)
        attrs = data["attrs"]
        fid = tuple(attrs["fid"])
        ea = data.get("ea", {})
        if data.get("created"):
            # client creates the data objects + writes the EA (§6.4.3)
            lsm = self.lov.create(
                stripe_count=stripe_count or self.default_stripe_count,
                stripe_size=stripe_size or self.default_stripe_size,
                stripe_offset=stripe_offset)
            self.lmv.mdc_for_fid(fid).reint(
                {"type": "setattr", "fid": fid, "ea": {"lov": lsm.to_ea()}})
        elif "lov" in ea:
            lsm = lov_mod.StripeMd.from_ea(ea["lov"])
        else:
            lsm = None
        fh = FileHandle(fid, lsm, data.get("open_handle", 0), flags)
        self.handles[id(fh)] = fh
        return fh

    def write(self, fh: FileHandle, data: bytes, offset: int | None = None,
              gid: int = 0) -> int:
        if fh.lsm is None:
            raise FsError(-22, "no stripe md")
        off = fh.pos if offset is None else offset
        n = self.lov.write(fh.lsm, off, data, gid=gid)
        fh.pos = off + n
        fh.max_written = max(fh.max_written, off + n)
        fh.mtime = self.sim.now
        self.sim.stats.add_bytes("fs.write", n)
        return n

    def read(self, fh: FileHandle, length: int,
             offset: int | None = None) -> bytes:
        if fh.lsm is None:
            raise FsError(-22, "no stripe md")
        off = fh.pos if offset is None else offset
        # PR-locked size query: flushes any writer's write-back cache
        # before we trust the OST sizes (§6.2.3 ordering); served from
        # the cached locks' value blocks when warm (zero RPCs)
        size = self.lov.getattr_locked(fh.lsm)["size"]
        length = max(0, min(length, size - off))
        if length == 0:
            return b""
        out = self.lov.read(fh.lsm, off, length)
        self._maybe_readahead(fh, off, len(out), size)
        fh.pos = off + len(out)
        self.sim.stats.add_bytes("fs.read", len(out))
        return out

    def _maybe_readahead(self, fh: FileHandle, off: int, nread: int,
                         size: int):
        """Per-handle sequential-read detector: a read starting exactly
        where the last one ended (or at 0 on a fresh handle) extends a
        readahead window that ramps up to `readahead_pages`, fetched
        stripe-aware into the OSC clean caches (one vectored OST_READ per
        stripe object). A seek resets the window."""
        ra_max = self.readahead_pages * osc_mod.PAGE_SIZE
        if ra_max <= 0:
            return
        if off != fh.ra_next:
            # seek: not sequential — back off, and forget the old fetch
            # horizon (a stale ra_pos ahead of a backward seek would
            # suppress refills for the whole re-scanned range; refetching
            # still-cached runs costs zero RPCs, readv skips them)
            fh.ra_window = 0
            fh.ra_pos = off + nread
            fh.ra_next = off + nread
            return
        fh.ra_next = off + nread
        fh.ra_window = min(ra_max, max(fh.ra_window * 2, ra_max // 4, 1))
        # hysteresis: refill only when less than half a window is still
        # ahead of the reader, and then fetch a FULL window — large
        # batched fetches, not a per-read top-up RPC
        ahead = fh.ra_pos - (off + nread)
        if ahead >= fh.ra_window // 2:
            return
        start = max(off + nread, fh.ra_pos)
        end = min(off + nread + fh.ra_window, size)
        if end > start:
            self.lov.readahead(fh.lsm, start, end - start)
            fh.ra_pos = end
            self.sim.stats.count("fs.readahead")
            self.sim.stats.add_bytes("fs.readahead", end - start)

    def fsync(self, fh: FileHandle):
        if fh.lsm is not None:
            self.sim.parallel([
                (lambda u=u: self.lov.by_uuid[u].flush())
                for u in {o["ost"] for o in fh.lsm.objects}])

    def close(self, fh: FileHandle):
        """Flush + ship size/mtime to the MDS (§6.9.1: the OSTs owned them
        while the file was open for write)."""
        self.fsync(fh)
        size = mtime = None
        if "w" in fh.flags or "c" in fh.flags:
            if fh.lsm is not None:
                a = self.lov.getattr(fh.lsm)
                size, mtime = a["size"], max(a["mtime"], fh.mtime)
        self.lmv.close(fh.fid, fh.open_handle, size, mtime)
        self.handles.pop(id(fh), None)

    # ------------------------------------------------------------- dirs
    def mkdir(self, path: str, mode: int = 0o755) -> tuple:
        parent, name = self._resolve_parent(path)
        if self.wbc and self.wbc.active and self.wbc.in_subtree(parent):
            return self.wbc.create(parent, name, "dir", mode)
        rep = self.lmv.reint({"type": "create", "parent": parent,
                              "name": name, "ftype": "dir", "mode": mode})
        self._invalidate(parent, name)
        return tuple(rep.data["fid"])

    def mkdir_p(self, path: str) -> tuple:
        fid = ROOT
        for i, name in enumerate(self._parts(path)):
            try:
                d = self._lookup(fid, name)
                if d.fid is None:
                    raise FsError(-2, name)
                fid = d.fid
            except FsError:
                fid = self.mkdir("/".join(self._parts(path)[:i + 1]))
        return tuple(fid)

    def readdir(self, path: str) -> dict:
        fid = self.resolve(path)
        return {k: tuple(v)
                for k, v in self.lmv.readdir(fid)["entries"].items()}

    def walk(self):
        """Iterative whole-namespace walk over readdir/getattr ground
        truth (split-directory buckets included via the LMV): yields
        (parent_fid, name, fid, attrs) for every directory entry. This is
        the 'initial scan' primitive Robinhood-style consumers bootstrap
        from (tools.audit.ChangelogAuditor(bootstrap=True))."""
        stack = [ROOT]
        seen = {ROOT}
        while stack:
            dfid = stack.pop()
            for name, fid in self.lmv.readdir(dfid)["entries"].items():
                fid = tuple(fid)
                attrs = self.lmv.getattr(fid)["attrs"]
                yield tuple(dfid), name, fid, attrs
                if attrs["type"] == "dir" and fid not in seen:
                    seen.add(fid)
                    stack.append(fid)

    def symlink(self, target: str, path: str):
        parent, name = self._resolve_parent(path)
        self.lmv.reint({"type": "create", "parent": parent, "name": name,
                        "ftype": "symlink", "target": target})
        self._invalidate(parent, name)

    def link(self, existing: str, path: str):
        fid = self.resolve(existing)
        parent, name = self._resolve_parent(path)
        self.lmv.reint({"type": "link", "parent": parent, "name": name,
                        "fid": fid})
        self._invalidate(parent, name)

    def rename(self, old: str, new: str):
        sp, sn = self._resolve_parent(old)
        dp, dn = self._resolve_parent(new)
        rep = self.lmv.reint({"type": "rename", "src": sp, "src_name": sn,
                              "dst": dp, "dst_name": dn})
        self._invalidate(sp, sn)
        self._invalidate(dp, dn)
        # rename-over displaced the old target's last link: destroy its
        # data objects exactly as unlink does
        self._destroy_from_reply(rep)

    def unlink(self, path: str):
        parent, name = self._resolve_parent(path)
        rep = self.lmv.reint({"type": "unlink", "parent": parent,
                              "name": name})
        self._invalidate(parent, name)
        self._destroy_from_reply(rep)

    rmdir = unlink

    def _destroy_from_reply(self, rep):
        """Last link gone (unlink or rename-over): the reply's LOV EA +
        llog cookies hand the object destroys to THE CLIENT; OSTs cancel
        the MDS records once their destroys commit (ch. 8.4)."""
        ea = (rep.data or {}).get("ea") or {}
        if "lov" in ea:
            lsm = lov_mod.StripeMd.from_ea(ea["lov"])
            self.lov.destroy(lsm, rep.data.get("cookies"))

    # ------------------------------------------------------------- stat
    def stat(self, path: str) -> dict:
        fid = self.resolve(path)
        d = self.lmv.getattr(fid, want_ea=True)
        a = d["attrs"]
        if a.get("mtime_on_ost") and "lov" in d.get("ea", {}):
            # size/mtime live on the OSTs while a writer is active (§6.9.1)
            lsm = lov_mod.StripeMd.from_ea(d["ea"]["lov"])
            oa = self.lov.getattr(lsm)
            a = dict(a, size=oa["size"], mtime=max(a["mtime"], oa["mtime"]))
        if "lov" in d.get("ea", {}):
            a["stripe_count"] = d["ea"]["lov"]["stripe_count"]
            a["stripe_size"] = d["ea"]["lov"]["stripe_size"]
        return a

    def exists(self, path: str) -> bool:
        try:
            self.resolve(path)
            return True
        except FsError:
            return False

    # -------------------------------------------------- jobid / changelog
    def set_jobid(self, jobid: str):
        """Tag every subsequent RPC from this client with a batch-job id
        (the JOBENV model): the same tag drives TBF NRS classification on
        servers and attribution in changelog records."""
        self.rpc.jobid = jobid

    def changelog_register(self, *, mdt: int = 0) -> str:
        return self.lmv.mdcs[mdt].changelog_register()

    def changelog_deregister(self, user: str, *, mdt: int = 0):
        self.lmv.mdcs[mdt].changelog_deregister(user)

    def changelog_read(self, user: str, *, mdt: int = 0,
                       since_idx: int | None = None,
                       count: int = 0) -> list[dict]:
        return self.lmv.mdcs[mdt].changelog_read(user, since_idx, count)

    def changelog_clear(self, user: str, up_to: int, *,
                        mdt: int = 0) -> dict:
        return self.lmv.mdcs[mdt].changelog_clear(user, up_to)

    def statfs(self) -> dict:
        mds = self.lmv.statfs()
        osts = [o.statfs() for o in self.lov.oscs]
        return {"mds": mds,
                "capacity": sum(o["capacity"] for o in osts),
                "free": sum(o["free"] for o in osts),
                "objects": sum(o["objects"] for o in osts)}

    # ----------------------------------------------------- wbc lifecycle
    def enable_wbc(self, path: str) -> bool:
        """Enter metadata write-back mode for a subtree (ch. 17)."""
        fid = self.resolve(path)
        wbc = mdc_mod.WbcCache(self.lmv, fid)
        if wbc.acquire():
            self.wbc = wbc
            return True
        return False

    def disable_wbc(self):
        if self.wbc:
            self.wbc.release()
            self.wbc = None

    def sync(self):
        if self.wbc:
            self.wbc.flush()
        self.lov.flush()
