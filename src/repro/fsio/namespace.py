"""Global namespace: mount-objects + automounter (paper ch. 3).

Per the paper's design (§3.4): a mount-object is an ORDINARY directory
with the setuid bit set, containing a `mntinfo` file whose content names
the target fileset ("fileset://name[@cell]"). Traversal INTO the
directory (not mere lookup OF it — the anti-mount-storm rule) triggers
the automounter, which grafts the target fileset's root into the path
walk. Mount-objects survive in the underlying fs as plain directories, so
they can be created/removed with standard APIs — the property the paper
argues for against AFS symlink magic.
"""
from __future__ import annotations

from typing import Callable

from repro.fsio.client import FsError, LustreClient

SETUID = 0o4000


class Automounter:
    """The fileset-location "database" + mount cache (§3.6).

    `filesets` maps "fileset://name" -> a callable returning a mounted
    LustreClient (lazy: filesets may live on other clusters)."""

    def __init__(self):
        self.filesets: dict[str, Callable[[], LustreClient]] = {}
        self.mounted: dict[str, LustreClient] = {}
        self.mounts = 0

    def register(self, uri: str, factory: Callable[[], LustreClient]):
        self.filesets[uri] = factory

    def mount(self, uri: str) -> LustreClient:
        fs = self.mounted.get(uri)
        if fs is None:
            if uri not in self.filesets:
                raise FsError(-2, f"unknown fileset {uri}")
            fs = self.mounted[uri] = self.filesets[uri]()
            self.mounts += 1
        return fs

    def expire(self, uri: str):
        """Release an idle fileset (autofs-style expiry)."""
        self.mounted.pop(uri, None)


def make_mount_object(fs: LustreClient, path: str, uri: str):
    """Create a mount-object: setuid directory + mntinfo file (§3.4)."""
    fid = fs.mkdir_p(path)
    fs.lmv.reint({"type": "setattr", "fid": fid,
                  "attrs": {"mode": 0o755 | SETUID}})
    fh = fs.creat(path.rstrip("/") + "/mntinfo", stripe_count=1)
    fs.write(fh, uri.encode())
    fs.close(fh)
    return fid


class GlobalNamespace:
    """Wraps a LustreClient with mount-object traversal."""

    def __init__(self, root_fs: LustreClient, automounter: Automounter):
        self.root_fs = root_fs
        self.amd = automounter

    def _resolve_fs(self, path: str) -> tuple[LustreClient, str]:
        """Walk from the root fs, following mount-objects; returns the
        filesystem owning the final component + the path within it."""
        fs = self.root_fs
        parts = [p for p in path.split("/") if p]
        i = 0
        base = []
        while i < len(parts):
            base.append(parts[i])
            sub = "/".join(base)
            try:
                st = fs.stat(sub)
            except FsError:
                break
            if st["type"] == "dir" and (st["mode"] & SETUID) \
                    and i + 1 <= len(parts):
                # traversal INTO the mount-object (or opendir) mounts it;
                # a bare stat of the object itself must NOT (§3.3).
                if i + 1 == len(parts):
                    break
                fh = fs.open(sub + "/mntinfo")
                uri = fs.read(fh, 4096).decode()
                fs.close(fh)
                fs = self.amd.mount(uri)
                parts = parts[i + 1:]
                base = []
                i = 0
                continue
            i += 1
        return fs, "/" + "/".join(parts)

    # --------------------------------------------------- forwarded ops
    def stat(self, path: str) -> dict:
        fs, p = self._resolve_fs(path)
        return fs.stat(p)

    def open(self, path: str, flags: str = "r", **kw):
        fs, p = self._resolve_fs(path)
        return fs, fs.open(p, flags, **kw)

    def readdir(self, path: str) -> dict:
        fs, p = self._resolve_fs(path)
        return fs.readdir(p)

    def read_file(self, path: str, length: int = 1 << 30) -> bytes:
        fs, fh = self.open(path)
        data = fs.read(fh, length)
        fs.close(fh)
        return data
