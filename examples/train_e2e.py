"""End-to-end training driver over the Lustre substrate.

Trains a small transformer (default ~27M params; --large for ~110M) for a
few hundred steps with:
  * the token corpus striped across OSTs (data pipeline),
  * parity-coded striped checkpoints every N steps,
  * an OST node failure injected mid-run (transparent failover),
  * a simulated trainer death + resume from the latest checkpoint.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--large]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import LustreCluster                       # noqa: E402
from repro.models.config import ModelConfig, RunConfig     # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig     # noqa: E402


def model_cfg(large: bool) -> ModelConfig:
    if large:   # ~110M params
        return ModelConfig(name="e2e-110m", family="transformer",
                           n_layers=12, d_model=768, n_heads=12,
                           n_kv_heads=4, head_dim=64, d_ff=2048,
                           vocab=8192)
    return ModelConfig(name="e2e-27m", family="transformer", n_layers=8,
                       d_model=448, n_heads=8, n_kv_heads=4, head_dim=56,
                       d_ff=1344, vocab=4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cluster = LustreCluster(osts=4, mdses=1, clients=2, ost_failover=True,
                            commit_interval=64)
    cfg = TrainerConfig(
        model=model_cfg(args.large),
        rc=RunConfig(seq_len=args.seq, global_batch=args.batch,
                     kind="train", attn_impl="ref"),
        n_steps=args.steps, ckpt_every=max(10, args.steps // 10),
        dataset_seqs=4096, n_writers=2, parity=True)

    n = cfg.model.n_params
    print(f"model: {cfg.model.name} ({n/1e6:.1f}M params), "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    half = args.steps // 2
    t0 = time.time()
    tr = Trainer(cluster, cfg)
    tr.run(half, fail_at={half // 2: lambda c: c.fail_node("ost1")})
    print(f"first {half} steps done (ost1 killed at {half//2}): "
          f"loss {tr.metrics[0]['loss']:.3f} -> {tr.metrics[-1]['loss']:.3f}")
    print("checkpoints:", tr.ckpt.steps())

    # trainer dies; a new one resumes from the latest complete checkpoint
    del tr
    tr2 = Trainer.resume(cluster, cfg)
    print(f"resumed at step {tr2.step}")
    tr2.run(args.steps - tr2.step)
    dt = time.time() - t0
    print(f"final loss {tr2.metrics[-1]['loss']:.4f} at step {tr2.step} "
          f"({dt:.0f}s wall, {cluster.now:.1f}s virtual-storage time)")
    st = cluster.stats
    print("storage: wrote", st.bytes.get("ost.write", 0) >> 20, "MiB,",
          "read", st.bytes.get("ost.read", 0) >> 20, "MiB,",
          st.counters.get("rpc.timeout", 0), "timeouts,",
          st.counters.get("rpc.replay", 0), "replays")


if __name__ == "__main__":
    main()
