"""Quickstart: a tour of the Lustre storage architecture.

Builds a 4-OST / 2-MDS cluster in-process, then walks through the paper's
headline features: striped files, intent-based metadata (1 RPC), the DLM,
unlink with llog-cookied object destruction, clustered metadata, failover,
and the collaborative read cache.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import LustreCluster                       # noqa: E402
from repro.core import cobd as cobd_mod                    # noqa: E402
from repro.fsio import LustreClient                        # noqa: E402


def main():
    cluster = LustreCluster(osts=4, mdses=2, clients=3,
                            ost_failover=True, commit_interval=32)
    fs = LustreClient(cluster).mount()
    print("== cluster: 4 OSTs (failover ring), 2 MDSes, 3 client nodes ==")

    # --- striping (ch. 10): a file striped over all 4 OSTs
    fs.mkdir_p("/proj/run1")
    fh = fs.creat("/proj/run1/data.bin", stripe_count=4, stripe_size=4096)
    payload = bytes(range(256)) * 256                     # 64 KiB
    fs.write(fh, payload)
    fs.close(fh)
    st = fs.stat("/proj/run1/data.bin")
    print(f"striped file: size={st['size']} stripes={st['stripe_count']}")

    # --- intent metadata (ch. 7.5): lookups are ONE rpc, then cached
    c0 = cluster.stats.counters.get("rpc.mds.ldlm_enqueue", 0)
    fs.stat("/proj/run1/data.bin")
    fs.stat("/proj/run1/data.bin")                        # dcache hit
    c1 = cluster.stats.counters.get("rpc.mds.ldlm_enqueue", 0)
    print(f"2 stats cost {c1 - c0} lock-intent RPCs "
          f"(dcache hits: {cluster.stats.counters.get('fs.dcache_hit', 0)})")

    # --- OST failover (ch. 11): kill ost0; reads fail over to the standby
    cluster.ost_targets[0].commit()
    cluster.lctl("fail", "ost0")
    fh = fs.open("/proj/run1/data.bin")
    assert fs.read(fh, 65536) == payload
    fs.close(fh)
    print("ost0 killed -> reads served via failover ring:",
          fs.lov.oscs[0].imp.active_nid)
    cluster.lctl("restart", "ost0")

    # --- collaborative cache (ch. 5.5): reads referred to a peer cache
    cobd, _ = cobd_mod.make_caching_node(
        cluster, "client1", cluster.ost_targets[1], "COBD-demo")
    reader = LustreClient(cluster, 2).mount()
    fh = reader.open("/proj/run1/data.bin")
    reader.read(fh, 65536)
    reader.close(fh)
    print("collaborative cache served",
          cluster.stats.bytes.get("cobd.served", 0), "bytes "
          f"(referrals: {cluster.stats.counters.get('ost.referral', 0)})")

    # --- unlink (ch. 8.4): EA+cookies back to client, objects destroyed
    objs = fs.statfs()["objects"]
    fs.unlink("/proj/run1/data.bin")
    print(f"unlink destroyed {objs - fs.statfs()['objects']} stripe objects "
          "(llog-cookied)")

    print(f"\nvirtual time elapsed: {cluster.now * 1e3:.2f} ms")
    print("RPC counters:", {k: v for k, v in sorted(
        cluster.stats.counters.items()) if k.startswith("rpc.")})


if __name__ == "__main__":
    main()
