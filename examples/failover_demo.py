"""Failure-mode walkthrough: every recovery mechanism, one at a time.

  1. OST crash with uncommitted writes  -> client transaction REPLAY
  2. lost reply                         -> reply-cache RESEND
  3. OST node death                     -> failover ring
  4. MDS crash                          -> intent replay (same fids)
  5. simultaneous 2-MDS failure         -> consistent-cut rollback
  6. dead OST disk under a checkpoint   -> parity-kernel reconstruction

Run:  PYTHONPATH=src python examples/failover_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np                                          # noqa: E402

from repro.ckpt import CheckpointManager                    # noqa: E402
from repro.core import LustreCluster                        # noqa: E402
from repro.fsio import LustreClient                         # noqa: E402


def banner(s):
    print(f"\n=== {s} ===")


def main():
    # ---------------------------------------------------------------- 1+2
    banner("1. OST crash: uncommitted writes recovered by client replay")
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    fh = fs.creat("/f.bin", stripe_count=2, stripe_size=64)
    fs.write(fh, b"critical training state" * 10)
    fs.fsync(fh)
    c.lctl("fail", "ost0")
    c.lctl("restart", "ost0")
    fh2 = fs.open("/f.bin")
    assert fs.read(fh2, 230) == b"critical training state" * 10
    print("data intact after crash;",
          c.stats.counters.get("rpc.replay", 0), "transactions replayed")

    banner("2. lost reply: resend answered from the server reply cache")
    c.lctl("drop_next", fs.rpc.nid, 1)
    fs.write(fh2, b"X", offset=0)
    fs.fsync(fh2)
    print("write survived a lost reply;",
          c.stats.counters.get("rpc.reply_cache_hit", 0), "cache hits,",
          c.stats.counters.get("rpc.timeout", 0), "timeout(s)")

    # ----------------------------------------------------------------- 3
    banner("3. OST node death: failover ring serves the target")
    c2 = LustreCluster(osts=3, mdses=1, clients=1, ost_failover=True,
                       commit_interval=4)
    fs2 = LustreClient(c2).mount()
    fh = fs2.creat("/g.bin", stripe_count=3, stripe_size=128)
    fs2.write(fh, bytes(range(256)) * 4)
    fs2.fsync(fh)
    for t in c2.ost_targets:
        t.commit()
    c2.lctl("fail", "ost1")                     # stays DOWN
    fh = fs2.open("/g.bin")
    assert fs2.read(fh, 1024) == bytes(range(256)) * 4
    print("reads OK with ost1 dead; OST0001 now served from:",
          fs2.lov.by_uuid["OST0001"].imp.active_nid)

    # ----------------------------------------------------------------- 4
    banner("4. MDS crash: intent-open replay recreates identical fids")
    c3 = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs3 = LustreClient(c3).mount()
    fh = fs3.creat("/will_survive.txt")
    fid = fh.fid
    fs3.close(fh)
    c3.lctl("fail", "mds0")
    c3.lctl("restart", "mds0")
    assert fs3.stat("/will_survive.txt")["fid"] == fid
    print(f"file survived MDS crash with the SAME fid {fid} "
          f"({c3.stats.counters.get('rpc.replay', 0)} replays)")

    # ----------------------------------------------------------------- 5
    banner("5. double-MDS power failure: consistent-cut rollback")
    c4 = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=6)
    fs4 = LustreClient(c4).mount()
    d = fs4.mkdir("/dir")                       # lands on mds1 (clustered)
    fs4.creat("/dir/a")
    for t in c4.mds_targets:
        t.commit()
    rec = c4.mds_recovery(fs4.rpc)
    # uncommitted cross-MDS op: rename into the remote dir
    fs4.creat("/b")
    fs4.rename("/b", "/dir/b")
    # whole-machine-room power-off: both MDSes AND the client die, so
    # nobody is left to replay the uncommitted tail (§6.7.6.3's scenario)
    c4.lctl("fail", "mds0")
    c4.lctl("fail", "mds1")
    c4.lctl("restart", "mds0")
    c4.lctl("restart", "mds1")
    rec2 = c4.mds_recovery(LustreClient(c4).mount().rpc)
    cut = rec2.rollback_after_failure()
    fresh = LustreClient(c4).mount()
    names = sorted(fresh.readdir("/dir"))
    root_names = sorted(fresh.readdir("/"))
    print(f"consistent cut {cut}; /dir = {names}, / = {root_names} "
          "(uncommitted cross-MDS rename rolled back on BOTH nodes)")
    assert "b" not in names and "a" in names
    assert "b" not in root_names

    # ----------------------------------------------------------------- 6
    banner("6. dead OST disk: checkpoint stripe rebuilt from parity")
    c5 = LustreCluster(osts=4, mdses=1, clients=2)
    writers = [LustreClient(c5, i).mount() for i in range(2)]
    cm = CheckpointManager(writers, stripe_count=3, stripe_size=4096,
                           parity=True)
    state = {"w": np.arange(64 * 64, dtype=np.float32).reshape(64, 64)}
    cm.save(1, state)
    # destroy one stripe object (disk loss, not node loss)
    fidea = writers[0].lmv.getattr(
        writers[0].resolve("/ckpt/step_00000001/w.bin"), want_ea=True)
    victim = fidea["ea"]["lov"]["objects"][0]
    ost = next(t for t in c5.ost_targets if t.uuid == victim["ost"])
    ost.obd.objects.pop((victim["group"], victim["oid"]))
    got, _ = cm.restore(1)
    assert (got["w"] == state["w"]).all()
    print("stripe object destroyed -> restore() reconstructed it "
          f"({c5.stats.counters.get('ckpt.stripe_reconstructed')} stripe, "
          "XOR parity Pallas kernel)")

    print("\nall six failure modes recovered ✓")


if __name__ == "__main__":
    main()
