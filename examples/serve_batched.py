"""Batched serving from a Lustre checkpoint.

Trains a tiny model for a handful of steps, checkpoints it to the striped
store, then a *separate* serving process restores the weights (read path,
collaborative-cache eligible) and decodes a batch of prompts in lockstep.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")

import jax                                                  # noqa: E402
import numpy as np                                          # noqa: E402

from repro.core import LustreCluster                        # noqa: E402
from repro.models.config import ModelConfig, RunConfig      # noqa: E402
from repro.models import registry, layers as L              # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig      # noqa: E402
from repro.train.serve import BatchedServer, Request        # noqa: E402


def main():
    cluster = LustreCluster(osts=4, mdses=1, clients=2, commit_interval=64)
    model = ModelConfig(name="serve-demo", family="transformer",
                        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        head_dim=32, d_ff=256, vocab=512)
    cfg = TrainerConfig(
        model=model,
        rc=RunConfig(seq_len=64, global_batch=4, kind="train",
                     attn_impl="ref"),
        n_steps=10, ckpt_every=10, dataset_seqs=256, n_writers=2,
        parity=False)
    tr = Trainer(cluster, cfg)
    tr.run()
    print("trained 10 steps, checkpointed at", tr.ckpt.steps())

    # ---- serving side: restore weights from the striped store
    tr2 = Trainer.resume(cluster, cfg)       # separate reader
    params = tr2.params
    srv = BatchedServer(model, params, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, list(rng.integers(1, 500, size=rng.integers(3, 9))),
                    max_new=8) for i in range(4)]
    out = srv.generate(reqs)
    for r in out:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    rd = cluster.stats.bytes.get("ost.read", 0)
    print(f"weights read from the striped store: {rd >> 10} KiB")


if __name__ == "__main__":
    main()
